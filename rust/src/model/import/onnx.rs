//! Minimal in-tree ONNX reader for the RNN checkpoint subset.
//!
//! A pure-std protobuf-subset decoder (varint + length-delimited fields,
//! nothing generated) over the ONNX `ModelProto` schema, followed by a
//! layout mapper that turns the graph's `LSTM`/`GRU`/`Gemm` initializers
//! into the Keras-convention tensors [`Weights`] pins:
//!
//! | ONNX | canonical tensor | conversion |
//! |---|---|---|
//! | `W (1, G·H, I)` | `rnn.w (I, G·H)` | transpose + gate-block reorder |
//! | `R (1, G·H, H)` | `rnn.u (H, G·H)` | transpose + gate-block reorder |
//! | LSTM `B (1, 8H)` | `rnn.b (4H)` | `Wb + Rb`, gate-block reorder |
//! | GRU `B (1, 6H)` | `rnn.b (2, 3H)` | rows stack as `Wb`, `Rb` |
//! | `Gemm B` (`transB=1`) | `<layer>.w (in, out)` | transpose |
//! | `Gemm C` | `<layer>.b (out)` | copy |
//!
//! Gate orders: ONNX LSTM blocks are `iofc`, Keras `ifco`; ONNX GRU
//! blocks are `zrh`, same as Keras.  Only forward single-direction RNNs
//! map onto [`Weights`], and GRUs must carry `linear_before_reset=1`
//! (Keras `reset_after`) or the two-row bias has no equivalent.
//!
//! Everything else in the graph — `Squeeze`/`Reshape` shaping, `Relu`
//! head activations, the final `Sigmoid`/`Softmax` — is walked for
//! validation but contributes no tensors.  All decode errors are typed
//! [`ImportError`]s; malformed bytes must never panic.
//!
//! [`Weights`]: crate::model::Weights

use std::collections::BTreeMap;

use super::{ImportError, TensorSource};
use crate::model::arch::{Arch, Cell, OutputActivation};
use crate::model::weights::Tensor;
use crate::model::zoo;

/// An ONNX checkpoint decoded down to canonical named tensors.
pub struct OnnxSource {
    pub arch: Arch,
    tensors: BTreeMap<String, Tensor>,
}

impl OnnxSource {
    /// Decode an ONNX `ModelProto` and map its initializers onto the
    /// canonical tensor names.  When `arch_hint` is `None` the
    /// architecture is inferred from the graph name (a model-zoo key
    /// like `top_gru`); a hint is enforced against the graph contents
    /// either way.
    pub fn parse(
        bytes: &[u8],
        arch_hint: Option<&Arch>,
    ) -> Result<Self, ImportError> {
        let graph = decode_model(bytes)?;
        convert(&graph, arch_hint)
    }
}

impl TensorSource for OnnxSource {
    fn arch(&self) -> Option<&Arch> {
        Some(&self.arch)
    }
    fn take(&mut self, name: &str) -> Option<Tensor> {
        self.tensors.remove(name)
    }
    fn remaining(&self) -> Vec<String> {
        self.tensors.keys().cloned().collect()
    }
}

// ---------------------------------------------------------------------
// Protobuf wire-format reader (the subset ONNX files use).
// ---------------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn done(&self) -> bool {
        self.pos >= self.buf.len()
    }

    fn err(&self, what: &str) -> ImportError {
        ImportError::Malformed {
            detail: format!("{what} at byte {}", self.pos),
        }
    }

    fn byte(&mut self) -> Result<u8, ImportError> {
        let b = *self
            .buf
            .get(self.pos)
            .ok_or_else(|| self.err("unexpected end of message"))?;
        self.pos += 1;
        Ok(b)
    }

    fn varint(&mut self) -> Result<u64, ImportError> {
        let mut out: u64 = 0;
        for i in 0..10u32 {
            let b = self.byte()?;
            if i == 9 && b > 1 {
                return Err(self.err("varint overflows u64"));
            }
            out |= u64::from(b & 0x7f) << (7 * i);
            if b & 0x80 == 0 {
                return Ok(out);
            }
        }
        Err(self.err("varint longer than 10 bytes"))
    }

    /// Field key: `(field_number, wire_type)`.
    fn key(&mut self) -> Result<(u64, u8), ImportError> {
        let k = self.varint()?;
        Ok((k >> 3, (k & 7) as u8))
    }

    /// Length-delimited payload (wire type 2).
    fn ld(&mut self) -> Result<&'a [u8], ImportError> {
        let len = usize::try_from(self.varint()?)
            .map_err(|_| self.err("length overflows usize"))?;
        let end = self
            .pos
            .checked_add(len)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| self.err("truncated length-delimited field"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn fixed32(&mut self) -> Result<[u8; 4], ImportError> {
        let end = self
            .pos
            .checked_add(4)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| self.err("truncated fixed32"))?;
        let mut a = [0u8; 4];
        a.copy_from_slice(&self.buf[self.pos..end]);
        self.pos = end;
        Ok(a)
    }

    fn skip(&mut self, wire: u8) -> Result<(), ImportError> {
        match wire {
            0 => {
                self.varint()?;
            }
            1 => {
                self.pos = self
                    .pos
                    .checked_add(8)
                    .filter(|&e| e <= self.buf.len())
                    .ok_or_else(|| self.err("truncated fixed64"))?;
            }
            2 => {
                self.ld()?;
            }
            5 => {
                self.fixed32()?;
            }
            other => {
                return Err(self.err(&format!("unsupported wire type {other}")))
            }
        }
        Ok(())
    }
}

fn utf8(bytes: &[u8], what: &str) -> Result<String, ImportError> {
    String::from_utf8(bytes.to_vec()).map_err(|_| ImportError::Malformed {
        detail: format!("{what} is not valid utf-8"),
    })
}

// ---------------------------------------------------------------------
// ModelProto → Graph decode.
// ---------------------------------------------------------------------

#[derive(Default)]
struct RawTensor {
    name: String,
    dims: Vec<usize>,
    dtype: u64,
    data: Vec<f32>,
}

#[derive(Default)]
struct Node {
    op: String,
    name: String,
    inputs: Vec<String>,
    outputs: Vec<String>,
    ints: BTreeMap<String, i64>,
    floats: BTreeMap<String, f32>,
    strs: BTreeMap<String, String>,
}

#[derive(Default)]
struct Graph {
    name: String,
    nodes: Vec<Node>,
    inits: BTreeMap<String, RawTensor>,
}

fn decode_model(bytes: &[u8]) -> Result<Graph, ImportError> {
    let mut r = Reader::new(bytes);
    let mut graph = None;
    while !r.done() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (7, 2) => graph = Some(decode_graph(r.ld()?)?),
            _ => r.skip(wire)?,
        }
    }
    graph.ok_or(ImportError::Malformed {
        detail: "model carries no graph".into(),
    })
}

fn decode_graph(bytes: &[u8]) -> Result<Graph, ImportError> {
    let mut r = Reader::new(bytes);
    let mut g = Graph::default();
    while !r.done() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 2) => g.nodes.push(decode_node(r.ld()?)?),
            (2, 2) => g.name = utf8(r.ld()?, "graph name")?,
            (5, 2) => {
                let t = decode_tensor(r.ld()?)?;
                g.inits.insert(t.name.clone(), t);
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(g)
}

fn decode_node(bytes: &[u8]) -> Result<Node, ImportError> {
    let mut r = Reader::new(bytes);
    let mut n = Node::default();
    while !r.done() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 2) => n.inputs.push(utf8(r.ld()?, "node input")?),
            (2, 2) => n.outputs.push(utf8(r.ld()?, "node output")?),
            (3, 2) => n.name = utf8(r.ld()?, "node name")?,
            (4, 2) => n.op = utf8(r.ld()?, "node op_type")?,
            (5, 2) => decode_attr(r.ld()?, &mut n)?,
            _ => r.skip(wire)?,
        }
    }
    Ok(n)
}

fn decode_attr(bytes: &[u8], node: &mut Node) -> Result<(), ImportError> {
    let mut r = Reader::new(bytes);
    let mut name = String::new();
    let mut ival = None;
    let mut fval = None;
    let mut sval = None;
    while !r.done() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 2) => name = utf8(r.ld()?, "attribute name")?,
            (2, 5) => fval = Some(f32::from_le_bytes(r.fixed32()?)),
            (3, 0) => ival = Some(r.varint()? as i64),
            (4, 2) => sval = Some(utf8(r.ld()?, "attribute string")?),
            _ => r.skip(wire)?,
        }
    }
    if name.is_empty() {
        return Err(ImportError::Malformed {
            detail: "attribute without a name".into(),
        });
    }
    if let Some(v) = ival {
        node.ints.insert(name.clone(), v);
    }
    if let Some(v) = fval {
        node.floats.insert(name.clone(), v);
    }
    if let Some(v) = sval {
        node.strs.insert(name, v);
    }
    Ok(())
}

fn decode_tensor(bytes: &[u8]) -> Result<RawTensor, ImportError> {
    let mut r = Reader::new(bytes);
    let mut t = RawTensor::default();
    let mut raw: Option<&[u8]> = None;
    while !r.done() {
        let (field, wire) = r.key()?;
        match (field, wire) {
            (1, 0) => t.dims.push(
                usize::try_from(r.varint()?)
                    .map_err(|_| r.err("tensor dim overflows usize"))?,
            ),
            (1, 2) => {
                // Packed repeated dims.
                let mut pr = Reader::new(r.ld()?);
                while !pr.done() {
                    t.dims.push(
                        usize::try_from(pr.varint()?).map_err(|_| {
                            pr.err("tensor dim overflows usize")
                        })?,
                    );
                }
            }
            (2, 0) => t.dtype = r.varint()?,
            (4, 2) => {
                // Packed float_data.
                let chunk = r.ld()?;
                if chunk.len() % 4 != 0 {
                    return Err(r.err("float_data not a multiple of 4 bytes"));
                }
                t.data.extend(
                    chunk
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
                );
            }
            (4, 5) => t.data.push(f32::from_le_bytes(r.fixed32()?)),
            (8, 2) => t.name = utf8(r.ld()?, "tensor name")?,
            (9, 2) => raw = Some(r.ld()?),
            _ => r.skip(wire)?,
        }
    }
    // `data_type` 1 is FLOAT; everything else is rejected up front so a
    // double/int64 export fails loudly instead of misparsing.
    if t.dtype != 1 {
        return Err(ImportError::BadDtype {
            name: t.name,
            got: match t.dtype {
                7 => "INT64".into(),
                10 => "FLOAT16".into(),
                11 => "DOUBLE".into(),
                other => format!("data_type {other}"),
            },
        });
    }
    if let Some(raw) = raw {
        if raw.len() % 4 != 0 {
            return Err(ImportError::Malformed {
                detail: format!(
                    "tensor {:?} raw_data length {} is not a multiple of 4",
                    t.name,
                    raw.len()
                ),
            });
        }
        t.data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
    }
    let numel = t
        .dims
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| ImportError::Malformed {
            detail: format!("tensor {:?} dims {:?} overflow", t.name, t.dims),
        })?;
    if numel != t.data.len() {
        return Err(ImportError::Malformed {
            detail: format!(
                "tensor {:?} carries {} elements but dims {:?} say {numel}",
                t.name,
                t.data.len(),
                t.dims
            ),
        });
    }
    Ok(t)
}

// ---------------------------------------------------------------------
// Graph → canonical tensors.
// ---------------------------------------------------------------------

fn convert(
    graph: &Graph,
    arch_hint: Option<&Arch>,
) -> Result<OnnxSource, ImportError> {
    let rnn_nodes: Vec<&Node> = graph
        .nodes
        .iter()
        .filter(|n| n.op == "LSTM" || n.op == "GRU")
        .collect();
    let rnn = match rnn_nodes.as_slice() {
        [one] => *one,
        other => {
            return Err(ImportError::Unsupported {
                what: format!(
                    "expected exactly one LSTM/GRU node, found {}",
                    other.len()
                ),
            })
        }
    };
    let cell = if rnn.op == "LSTM" { Cell::Lstm } else { Cell::Gru };
    let arch = resolve_arch(graph, cell, arch_hint)?;

    if let Some(d) = rnn.strs.get("direction") {
        if d != "forward" {
            return Err(ImportError::Unsupported {
                what: format!(
                    "direction {d:?} (only forward single-direction RNNs \
                     map onto Weights)"
                ),
            });
        }
    }
    if let Some(&hs) = rnn.ints.get("hidden_size") {
        if hs != arch.hidden_size as i64 {
            return Err(ImportError::ArchMismatch {
                detail: format!(
                    "hidden_size attribute {hs} != {} of {}",
                    arch.hidden_size,
                    arch.key()
                ),
            });
        }
    }
    if cell == Cell::Gru
        && rnn.ints.get("linear_before_reset").copied().unwrap_or(0) != 1
    {
        return Err(ImportError::Unsupported {
            what: "GRU without linear_before_reset=1 (Keras reset_after): \
                   its bias layout has no Weights equivalent"
                .into(),
        });
    }
    for extra in rnn.inputs.iter().skip(4) {
        if !extra.is_empty() {
            return Err(ImportError::Unsupported {
                what: format!(
                    "{} optional input {extra:?} (sequence_lens / initial \
                     state / peepholes)",
                    rnn.op
                ),
            });
        }
    }

    let g = cell.gates();
    let (i, h) = (arch.input_size, arch.hidden_size);
    // Keras gate block `k` reads ONNX gate block `order[k]`:
    // LSTM `ifco` ← `iofc`, GRU `zrh` ← `zrh`.
    let order: &[usize] = match cell {
        Cell::Lstm => &[0, 2, 3, 1],
        Cell::Gru => &[0, 1, 2],
    };

    let input_name = |idx: usize, what: &str| -> Result<&str, ImportError> {
        match rnn.inputs.get(idx) {
            Some(s) if !s.is_empty() => Ok(s.as_str()),
            _ => Err(ImportError::MissingTensor {
                name: format!("{what} ({} input #{idx})", rnn.op),
            }),
        }
    };
    let init = |name: &str| -> Result<&RawTensor, ImportError> {
        graph.inits.get(name).ok_or_else(|| ImportError::MissingTensor {
            name: name.to_string(),
        })
    };

    let mut tensors: BTreeMap<String, Tensor> = BTreeMap::new();
    tensors
        .insert("rnn.w".into(), unblock(init(input_name(1, "W")?)?, h, i, order)?);
    tensors
        .insert("rnn.u".into(), unblock(init(input_name(2, "R")?)?, h, h, order)?);
    tensors
        .insert("rnn.b".into(), rnn_bias(init(input_name(3, "B")?)?, cell, h, order)?);

    // The dense head hangs off the final hidden state (Y_h, output #1).
    let mut cur = rnn
        .outputs
        .iter()
        .rev()
        .find(|s| !s.is_empty())
        .cloned()
        .ok_or_else(|| ImportError::Malformed {
            detail: format!("{} node has no outputs", rnn.op),
        })?;

    let mut head: Vec<(String, usize, bool)> = arch
        .dense_sizes
        .iter()
        .enumerate()
        .map(|(idx, &size)| (format!("dense{idx}"), size, true))
        .collect();
    head.push(("out".into(), arch.output_size, false));

    let mut prev = h;
    for (lname, size, relu) in head {
        let node = next_significant(graph, &mut cur)?;
        if node.op != "Gemm" {
            return Err(ImportError::Unsupported {
                what: format!(
                    "op {:?} in the dense head (expected Gemm for {lname})",
                    node.op
                ),
            });
        }
        for (attr, want) in [("alpha", 1.0f32), ("beta", 1.0)] {
            if let Some(&v) = node.floats.get(attr) {
                if v != want {
                    return Err(ImportError::Unsupported {
                        what: format!(
                            "Gemm {lname} with {attr}={v} (only 1.0 maps \
                             onto Weights)"
                        ),
                    });
                }
            }
        }
        if node.ints.get("transA").copied().unwrap_or(0) != 0 {
            return Err(ImportError::Unsupported {
                what: format!("Gemm {lname} with transA=1"),
            });
        }
        let wn = node.inputs.get(1).filter(|s| !s.is_empty()).ok_or_else(
            || ImportError::MissingTensor {
                name: format!("{lname}.w (Gemm weight input)"),
            },
        )?;
        let bn = node.inputs.get(2).filter(|s| !s.is_empty()).ok_or_else(
            || ImportError::Unsupported {
                what: format!("Gemm {lname} without a bias input"),
            },
        )?;
        let transb = node.ints.get("transB").copied().unwrap_or(0) != 0;
        tensors.insert(
            format!("{lname}.w"),
            gemm_weight(init(wn)?, prev, size, transb)?,
        );
        let bt = init(bn)?;
        if bt.dims != [size] {
            return Err(ImportError::ShapeMismatch {
                name: bt.name.clone(),
                want: vec![size],
                got: bt.dims.clone(),
            });
        }
        tensors.insert(
            format!("{lname}.b"),
            Tensor { shape: vec![size], data: bt.data.clone() },
        );
        cur = first_output(node)?.to_string();
        if relu {
            let act = next_significant(graph, &mut cur)?;
            if act.op != "Relu" {
                return Err(ImportError::Unsupported {
                    what: format!(
                        "activation {:?} after {lname} (the Keras head \
                         uses ReLU)",
                        act.op
                    ),
                });
            }
            cur = first_output(act)?.to_string();
        }
        prev = size;
    }

    let act = next_significant(graph, &mut cur)?;
    let want_act = match arch.output_activation {
        OutputActivation::Sigmoid => "Sigmoid",
        OutputActivation::Softmax => "Softmax",
    };
    if act.op != want_act {
        return Err(ImportError::ArchMismatch {
            detail: format!(
                "output activation {:?} but {} ends with {want_act}",
                act.op,
                arch.key()
            ),
        });
    }

    Ok(OnnxSource { arch, tensors })
}

fn resolve_arch(
    graph: &Graph,
    cell: Cell,
    hint: Option<&Arch>,
) -> Result<Arch, ImportError> {
    if let Some(a) = hint {
        if a.cell != cell {
            return Err(ImportError::ArchMismatch {
                detail: format!(
                    "graph holds a {} but {} was requested",
                    cell.label(),
                    a.key()
                ),
            });
        }
        return Ok(a.clone());
    }
    let inferred = graph.name.rsplit_once('_').and_then(|(name, cell_str)| {
        let c: Cell = cell_str.parse().ok()?;
        zoo::arch(name, c).ok()
    });
    match inferred {
        Some(a) if a.cell == cell => Ok(a),
        Some(a) => Err(ImportError::ArchMismatch {
            detail: format!(
                "graph name {:?} says {} but the graph holds a {} node",
                graph.name,
                a.cell.label(),
                cell.label()
            ),
        }),
        None => Err(ImportError::Unsupported {
            what: format!(
                "graph name {:?} is not a model-zoo key; pass the \
                 architecture explicitly",
                graph.name
            ),
        }),
    }
}

/// ONNX recurrent kernel `(1, G·H, cols)` (gate-blocked rows) → Keras
/// `(cols, G·H)`: transpose, with Keras gate block `k` reading ONNX
/// block `order[k]`.
fn unblock(
    t: &RawTensor,
    h: usize,
    cols: usize,
    order: &[usize],
) -> Result<Tensor, ImportError> {
    let gh = order.len() * h;
    let want = vec![1, gh, cols];
    if t.dims != want {
        return Err(ImportError::ShapeMismatch {
            name: t.name.clone(),
            want,
            got: t.dims.clone(),
        });
    }
    let mut data = vec![0.0f32; gh * cols];
    for (kb, &ob) in order.iter().enumerate() {
        for j in 0..h {
            let src_row = ob * h + j;
            let dst_col = kb * h + j;
            for c in 0..cols {
                data[c * gh + dst_col] = t.data[src_row * cols + c];
            }
        }
    }
    Ok(Tensor { shape: vec![cols, gh], data })
}

/// ONNX RNN bias `(1, 2·G·H)` = `Wb | Rb` → the Keras bias layout.
fn rnn_bias(
    t: &RawTensor,
    cell: Cell,
    h: usize,
    order: &[usize],
) -> Result<Tensor, ImportError> {
    let g = order.len();
    let want = vec![1, 2 * g * h];
    if t.dims != want {
        return Err(ImportError::ShapeMismatch {
            name: t.name.clone(),
            want,
            got: t.dims.clone(),
        });
    }
    match cell {
        Cell::Lstm => {
            // Keras LSTM has one bias vector; ONNX splits Wb | Rb.  Sum
            // them — the standard Keras→ONNX export writes Rb = 0, which
            // makes the sum bit-exact.
            let mut data = vec![0.0f32; 4 * h];
            for (kb, &ob) in order.iter().enumerate() {
                for j in 0..h {
                    data[kb * h + j] =
                        t.data[ob * h + j] + t.data[(g + ob) * h + j];
                }
            }
            Ok(Tensor { shape: vec![4 * h], data })
        }
        Cell::Gru => {
            // `zrh` blocks already match Keras; the two halves stack as
            // rows of the `(2, 3H)` reset_after bias (row 0 = input
            // bias Wb, row 1 = recurrent bias Rb).
            Ok(Tensor { shape: vec![2, 3 * h], data: t.data.clone() })
        }
    }
}

/// Gemm weight → Keras `(in, out)`; `transB=1` stores `(out, in)`.
fn gemm_weight(
    t: &RawTensor,
    input: usize,
    output: usize,
    transb: bool,
) -> Result<Tensor, ImportError> {
    let want = if transb { vec![output, input] } else { vec![input, output] };
    if t.dims != want {
        return Err(ImportError::ShapeMismatch {
            name: t.name.clone(),
            want,
            got: t.dims.clone(),
        });
    }
    if !transb {
        return Ok(Tensor { shape: vec![input, output], data: t.data.clone() });
    }
    let mut data = vec![0.0f32; input * output];
    for r in 0..output {
        for c in 0..input {
            data[c * output + r] = t.data[r * input + c];
        }
    }
    Ok(Tensor { shape: vec![input, output], data })
}

fn consumer<'g>(graph: &'g Graph, output: &str) -> Option<&'g Node> {
    graph
        .nodes
        .iter()
        .find(|n| n.inputs.iter().any(|i| i == output))
}

fn first_output(node: &Node) -> Result<&str, ImportError> {
    node.outputs
        .first()
        .map(String::as_str)
        .filter(|s| !s.is_empty())
        .ok_or_else(|| ImportError::Malformed {
            detail: format!("node {:?} has no output", node.name),
        })
}

/// Follow the data flow from `cur` to the next non-shaping node,
/// stepping through `Squeeze`/`Reshape`/… outputs.  Bounded by the node
/// count so a malformed self-referential graph errors instead of
/// spinning.
fn next_significant<'g>(
    graph: &'g Graph,
    cur: &mut String,
) -> Result<&'g Node, ImportError> {
    for _ in 0..=graph.nodes.len() {
        let node = consumer(graph, cur).ok_or_else(|| {
            ImportError::Malformed {
                detail: format!("dangling graph: nothing consumes {cur:?}"),
            }
        })?;
        match node.op.as_str() {
            "Squeeze" | "Unsqueeze" | "Reshape" | "Flatten" | "Identity"
            | "Transpose" | "Cast" => {
                *cur = first_output(node)?.to_string();
            }
            _ => return Ok(node),
        }
    }
    Err(ImportError::Malformed {
        detail: "shaping-op cycle in graph".into(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip_and_limits() {
        // 300 = 0b1_0101100 → [0xAC, 0x02].
        let mut r = Reader::new(&[0xAC, 0x02]);
        assert_eq!(r.varint().unwrap(), 300);
        assert!(r.done());
        // u64::MAX is ten bytes ending in 0x01.
        let max = [0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0x01];
        assert_eq!(Reader::new(&max).varint().unwrap(), u64::MAX);
        // Truncated and over-long varints are typed errors, not panics.
        assert!(Reader::new(&[0x80]).varint().is_err());
        let over = [0xFF; 11];
        assert!(Reader::new(&over).varint().is_err());
    }

    #[test]
    fn ld_rejects_length_past_end() {
        // Claims 100 bytes, supplies 1.
        let mut r = Reader::new(&[0x64, 0x00]);
        assert!(r.ld().is_err());
    }

    #[test]
    fn empty_model_is_typed_error() {
        let err = OnnxSource::parse(&[], None).unwrap_err();
        assert!(matches!(err, ImportError::Malformed { .. }), "{err}");
    }

    #[test]
    fn garbage_is_typed_error() {
        for seed in 0u8..8 {
            let bytes: Vec<u8> =
                (0..64u32).map(|i| (i as u8).wrapping_mul(37) ^ seed).collect();
            // Must return (any) error, never panic.
            assert!(OnnxSource::parse(&bytes, None).is_err());
        }
    }
}
