//! Architecture descriptors, mirroring `python/compile/model.py::Arch`.

use crate::util::json::Value;

/// Recurrent cell type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Cell {
    Lstm,
    Gru,
}

impl Cell {
    /// Number of packed gates: the 4 matmuls of Eq. 1 for LSTM, 3 for GRU
    /// — the source of the paper's "GRU uses ~1/4 less resources".
    pub fn gates(&self) -> usize {
        match self {
            Cell::Lstm => 4,
            Cell::Gru => 3,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Cell::Lstm => "lstm",
            Cell::Gru => "gru",
        }
    }
}

impl std::str::FromStr for Cell {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "lstm" => Ok(Cell::Lstm),
            "gru" => Ok(Cell::Gru),
            other => anyhow::bail!("unknown cell {other:?} (want lstm|gru)"),
        }
    }
}

/// Final-layer activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputActivation {
    /// Binary classifier (top tagging).
    Sigmoid,
    /// Multi-class (flavor tagging, QuickDraw).
    Softmax,
}

impl std::str::FromStr for OutputActivation {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sigmoid" => Ok(OutputActivation::Sigmoid),
            "softmax" => Ok(OutputActivation::Softmax),
            other => anyhow::bail!("unknown output activation {other:?}"),
        }
    }
}

/// One benchmark model: a row of Table 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Arch {
    /// Benchmark name: "top" | "flavor" | "quickdraw".
    pub name: String,
    pub cell: Cell,
    pub seq_len: usize,
    pub input_size: usize,
    pub hidden_size: usize,
    /// Hidden dense-head layer sizes (Table 1 "Dense layer sizes").
    pub dense_sizes: Vec<usize>,
    pub output_size: usize,
    pub output_activation: OutputActivation,
}

impl Arch {
    /// `"{name}_{cell}"`, e.g. `top_gru` — the artifact key.
    pub fn key(&self) -> String {
        format!("{}_{}", self.name, self.cell.label())
    }

    /// Parse from the `"arch"` object of the weights/manifest JSON.
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            cell: v.req("cell")?.as_str()?.parse()?,
            seq_len: v.req("seq_len")?.as_usize()?,
            input_size: v.req("input_size")?.as_usize()?,
            hidden_size: v.req("hidden_size")?.as_usize()?,
            dense_sizes: v.req("dense_sizes")?.as_usize_vec()?,
            output_size: v.req("output_size")?.as_usize()?,
            output_activation: v.req("output_activation")?.as_str()?.parse()?,
        })
    }

    /// Trainable parameters in the recurrent layer (Table 1 LSTM/GRU
    /// columns).  The GRU follows Keras `reset_after=True`, whose two
    /// bias rows give the paper's 1680/46080/51072 counts.
    pub fn rnn_param_count(&self) -> usize {
        let (i, h) = (self.input_size, self.hidden_size);
        match self.cell {
            Cell::Lstm => 4 * (i * h + h * h + h),
            Cell::Gru => 3 * (i * h + h * h) + 2 * 3 * h,
        }
    }

    /// Trainable parameters in the dense head (Table 1 "Non-RNN layers").
    pub fn non_rnn_param_count(&self) -> usize {
        let mut total = 0;
        let mut prev = self.hidden_size;
        for &size in self.dense_sizes.iter().chain([self.output_size].iter()) {
            total += prev * size + size;
            prev = size;
        }
        total
    }

    pub fn param_count(&self) -> usize {
        self.rnn_param_count() + self.non_rnn_param_count()
    }

    /// Multiplications in one recurrent state update: the kernel matmul
    /// (`I×gH`) and the recurrent-kernel matmul (`H×gH`), reported
    /// separately because hls4ml gives each its own reuse factor
    /// (the `R = (X, Y)` pairs of Tables 2–4).
    pub fn rnn_mults_per_step(&self) -> (usize, usize) {
        let g = self.cell.gates();
        (
            self.input_size * g * self.hidden_size,
            self.hidden_size * g * self.hidden_size,
        )
    }

    /// Number of classes for dataset purposes (1 == binary/sigmoid).
    pub fn n_classes(&self) -> usize {
        self.output_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn key_format() {
        assert_eq!(zoo::arch("top", Cell::Gru).unwrap().key(), "top_gru");
    }

    #[test]
    fn cell_from_str() {
        assert_eq!("LSTM".parse::<Cell>().unwrap(), Cell::Lstm);
        assert_eq!("gru".parse::<Cell>().unwrap(), Cell::Gru);
        assert!("rnn".parse::<Cell>().is_err());
    }

    #[test]
    fn gates_ratio_is_3_to_4() {
        assert_eq!(Cell::Gru.gates(), 3);
        assert_eq!(Cell::Lstm.gates(), 4);
    }

    #[test]
    fn mults_per_step_top() {
        let a = zoo::arch("top", Cell::Lstm).unwrap();
        let (k, r) = a.rnn_mults_per_step();
        assert_eq!(k, 6 * 80); // 480
        assert_eq!(r, 20 * 80); // 1600
    }

    #[test]
    fn arch_from_json() {
        let v = crate::util::json::parse(
            r#"{"name":"top","cell":"gru","seq_len":20,"input_size":6,
                "hidden_size":20,"dense_sizes":[64],"output_size":1,
                "output_activation":"sigmoid"}"#,
        )
        .unwrap();
        let a = Arch::from_json(&v).unwrap();
        assert_eq!(a, zoo::arch("top", Cell::Gru).unwrap());
    }

    #[test]
    fn arch_from_json_rejects_missing() {
        let v = crate::util::json::parse(r#"{"name":"top"}"#).unwrap();
        assert!(Arch::from_json(&v).is_err());
    }
}
