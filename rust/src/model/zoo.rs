//! The six benchmark configurations of Table 1.

use super::arch::{Arch, Cell, OutputActivation};

/// Benchmark names in paper order.
pub const BENCHMARKS: [&str; 3] = ["top", "flavor", "quickdraw"];

/// Construct one of the six benchmark architectures.
pub fn arch(name: &str, cell: Cell) -> anyhow::Result<Arch> {
    let a = match name {
        "top" => Arch {
            name: "top".into(),
            cell,
            seq_len: 20,
            input_size: 6,
            hidden_size: 20,
            dense_sizes: vec![64],
            output_size: 1,
            output_activation: OutputActivation::Sigmoid,
        },
        "flavor" => Arch {
            name: "flavor".into(),
            cell,
            seq_len: 15,
            input_size: 6,
            hidden_size: 120,
            dense_sizes: vec![50, 10],
            output_size: 3,
            output_activation: OutputActivation::Softmax,
        },
        "quickdraw" => Arch {
            name: "quickdraw".into(),
            cell,
            seq_len: 100,
            input_size: 3,
            hidden_size: 128,
            dense_sizes: vec![256, 128],
            output_size: 5,
            output_activation: OutputActivation::Softmax,
        },
        other => anyhow::bail!("unknown benchmark {other:?} (want one of {BENCHMARKS:?})"),
    };
    Ok(a)
}

/// All six variants, paper order (top, flavor, quickdraw) × (lstm, gru).
pub fn all_archs() -> Vec<Arch> {
    BENCHMARKS
        .iter()
        .flat_map(|name| {
            [Cell::Lstm, Cell::Gru]
                .into_iter()
                .map(move |cell| arch(name, cell).expect("static zoo"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1 + §4 text: exact trainable-parameter counts.
    #[test]
    fn param_counts_match_table1() {
        let cases = [
            ("top", Cell::Lstm, 2160, 1409, 3569),
            ("top", Cell::Gru, 1680, 1409, 3089),
            ("flavor", Cell::Lstm, 60960, 6593, 67553),
            ("flavor", Cell::Gru, 46080, 6593, 52673),
            ("quickdraw", Cell::Lstm, 67584, 66565, 134149),
            ("quickdraw", Cell::Gru, 51072, 66565, 117637),
        ];
        for (name, cell, rnn, non_rnn, total) in cases {
            let a = arch(name, cell).unwrap();
            assert_eq!(a.rnn_param_count(), rnn, "{name} {cell:?} rnn");
            assert_eq!(a.non_rnn_param_count(), non_rnn, "{name} {cell:?} head");
            assert_eq!(a.param_count(), total, "{name} {cell:?} total");
        }
    }

    #[test]
    fn all_archs_has_six() {
        let archs = all_archs();
        assert_eq!(archs.len(), 6);
        let keys: Vec<String> = archs.iter().map(|a| a.key()).collect();
        assert!(keys.contains(&"quickdraw_gru".to_string()));
    }

    #[test]
    fn unknown_rejected() {
        assert!(arch("higgs", Cell::Lstm).is_err());
    }
}
