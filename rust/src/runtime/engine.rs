//! PJRT execution engine: compiled executables + resident weight buffers.
//!
//! One [`PjrtModel`] wraps one HLO module (model × batch bucket) compiled
//! on the PJRT CPU client.  Weights are uploaded to device buffers once
//! at load time; the per-request hot path only transfers the input batch
//! (`buffer_from_host_buffer`) and runs `execute_b` — no Python, no
//! recompilation, no weight copies.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use crate::util::sync::{lock_or_recover, Mutex};

use crate::model::Weights;

use super::manifest::{Manifest, ManifestModel};

/// One compiled (model × batch) executable with resident weights.
pub struct PjrtModel {
    pub key: String,
    pub batch: usize,
    pub seq_len: usize,
    pub input_size: usize,
    pub output_size: usize,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

impl PjrtModel {
    /// Flat input length expected by [`Self::run_batch`] when full.
    pub fn input_len(&self) -> usize {
        self.batch * self.seq_len * self.input_size
    }

    /// Execute on up to `batch` samples.  `xs` holds `n` samples row-major
    /// (`n * seq_len * input_size` floats); if `n < batch` the batch is
    /// zero-padded and only the first `n` outputs are returned.
    pub fn run_batch(&self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let stride = self.seq_len * self.input_size;
        anyhow::ensure!(n >= 1 && n <= self.batch, "n={n} vs batch {}", self.batch);
        anyhow::ensure!(xs.len() == n * stride, "xs len {} != {}", xs.len(), n * stride);

        let input_buf = if n == self.batch {
            self.client.buffer_from_host_buffer(
                xs,
                &[self.batch, self.seq_len, self.input_size],
                None,
            )?
        } else {
            let mut padded = vec![0f32; self.input_len()];
            padded[..xs.len()].copy_from_slice(xs);
            self.client.buffer_from_host_buffer(
                &padded,
                &[self.batch, self.seq_len, self.input_size],
                None,
            )?
        };

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(&input_buf);
        args.extend(self.weight_bufs.iter());
        let result = self.exe.execute_b(&args)?;
        // return_tuple=True → single tuple output on device 0.
        let literal = result[0][0].to_literal_sync()?.to_tuple1()?;
        let flat = literal.to_vec::<f32>()?;
        anyhow::ensure!(
            flat.len() == self.batch * self.output_size,
            "output length {} != {}",
            flat.len(),
            self.batch * self.output_size
        );
        Ok(flat
            .chunks_exact(self.output_size)
            .take(n)
            .map(|row| row.to_vec())
            .collect())
    }
}

/// PJRT client + executable cache over a manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<(String, usize), Arc<PjrtModel>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over the artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load (or fetch cached) the executable for `key` at `batch`.
    pub fn model(&self, key: &str, batch: usize) -> anyhow::Result<Arc<PjrtModel>> {
        if let Some(hit) =
            lock_or_recover(&self.cache).get(&(key.to_string(), batch))
        {
            return Ok(hit.clone());
        }
        let model = Arc::new(self.compile(key, batch)?);
        lock_or_recover(&self.cache)
            .insert((key.to_string(), batch), model.clone());
        Ok(model)
    }

    /// Smallest batch bucket that fits `n` samples (or the largest bucket).
    pub fn bucket_for(&self, key: &str, n: usize) -> anyhow::Result<usize> {
        let buckets = self.manifest.batch_buckets(key)?;
        anyhow::ensure!(!buckets.is_empty(), "no HLO artifacts for {key}");
        Ok(buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or(*buckets.last().expect("non-empty")))
    }

    fn compile(&self, key: &str, batch: usize) -> anyhow::Result<PjrtModel> {
        let entry: &ManifestModel = self.manifest.model(key)?;
        let rel = entry.hlo.get(&batch).ok_or_else(|| {
            anyhow::anyhow!(
                "no HLO for {key} at batch {batch} (have {:?})",
                entry.hlo.keys().collect::<Vec<_>>()
            )
        })?;
        let hlo_path = self.manifest.path(rel);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;

        // Upload weights once, in the manifest's parameter order.
        let weights = Weights::load(self.manifest.path(&entry.weights))?;
        let mut weight_bufs = Vec::with_capacity(entry.param_order.len());
        for (layer, tensor) in &entry.param_order {
            let t = weights.tensor(layer, tensor)?;
            weight_bufs.push(self.client.buffer_from_host_buffer(
                &t.data,
                &t.shape,
                None,
            )?);
        }
        Ok(PjrtModel {
            key: key.to_string(),
            batch,
            seq_len: entry.seq_len,
            input_size: entry.input_size,
            output_size: entry.output_size,
            exe,
            client: self.client.clone(),
            weight_bufs,
        })
    }
}

// NOTE: integration tests for this module live in rust/tests/pjrt.rs —
// they need the real artifacts directory (built by `make artifacts`).
