//! Artifact registry: `artifacts/manifest.json`, written by
//! `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::parse;

/// One model entry of the manifest.
#[derive(Debug, Clone)]
pub struct ManifestModel {
    pub key: String,
    pub benchmark: String,
    pub cell: String,
    pub seq_len: usize,
    pub input_size: usize,
    pub hidden_size: usize,
    pub output_size: usize,
    /// Relative paths.
    pub weights: String,
    pub dataset: String,
    pub golden: String,
    /// batch size → relative HLO path.
    pub hlo: BTreeMap<usize, String>,
    /// HLO parameter order (parameters 1..N): (layer, tensor).
    pub param_order: Vec<(String, String)>,
}

/// Parsed manifest + its root directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: Vec<ManifestModel>,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> anyhow::Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "reading {} (run `make artifacts` first?): {e}",
                path.display()
            )
        })?;
        Self::from_json(root, &text)
    }

    pub fn from_json(root: PathBuf, text: &str) -> anyhow::Result<Self> {
        let doc = parse(text)?;
        let format = doc.req("format")?.as_str()?;
        anyhow::ensure!(
            format == "hlo-text-v1",
            "unsupported manifest format {format:?}"
        );
        let mut models = Vec::new();
        for entry in doc.req("models")?.as_array()? {
            let mut hlo = BTreeMap::new();
            for (batch, path) in entry.req("hlo")?.as_object()? {
                hlo.insert(
                    batch.parse::<usize>().map_err(|e| {
                        anyhow::anyhow!("bad batch key {batch:?}: {e}")
                    })?,
                    path.as_str()?.to_string(),
                );
            }
            let mut param_order = Vec::new();
            for pair in entry.req("param_order")?.as_array()? {
                let pair = pair.as_array()?;
                anyhow::ensure!(pair.len() == 2, "param_order pair");
                param_order.push((
                    pair[0].as_str()?.to_string(),
                    pair[1].as_str()?.to_string(),
                ));
            }
            models.push(ManifestModel {
                key: entry.req("key")?.as_str()?.to_string(),
                benchmark: entry.req("benchmark")?.as_str()?.to_string(),
                cell: entry.req("cell")?.as_str()?.to_string(),
                seq_len: entry.req("seq_len")?.as_usize()?,
                input_size: entry.req("input_size")?.as_usize()?,
                hidden_size: entry.req("hidden_size")?.as_usize()?,
                output_size: entry.req("output_size")?.as_usize()?,
                weights: entry.req("weights")?.as_str()?.to_string(),
                dataset: entry.req("dataset")?.as_str()?.to_string(),
                golden: entry.req("golden")?.as_str()?.to_string(),
                hlo,
                param_order,
            });
        }
        Ok(Self { root, models })
    }

    pub fn model(&self, key: &str) -> anyhow::Result<&ManifestModel> {
        self.models.iter().find(|m| m.key == key).ok_or_else(|| {
            let keys: Vec<&str> =
                self.models.iter().map(|m| m.key.as_str()).collect();
            anyhow::anyhow!("no model {key:?} in manifest (have {keys:?})")
        })
    }

    /// Absolute path for a manifest-relative path.
    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// The batch buckets available for a model (ascending).
    pub fn batch_buckets(&self, key: &str) -> anyhow::Result<Vec<usize>> {
        Ok(self.model(key)?.hlo.keys().copied().collect())
    }
}

/// Find the artifacts directory: `$RNN_HLS_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("RNN_HLS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
          "format": "hlo-text-v1",
          "models": [{
            "key": "top_gru", "benchmark": "top", "cell": "gru",
            "seq_len": 20, "input_size": 6, "hidden_size": 20,
            "output_size": 1,
            "weights": "weights/top_gru.json",
            "dataset": "data/top_test.bin",
            "golden": "golden/top_gru.json",
            "hlo": {"1": "hlo/top_gru_b1.hlo.txt", "10": "hlo/top_gru_b10.hlo.txt"},
            "param_order": [["dense0","b"],["rnn","w"]]
          }]
        }"#
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(PathBuf::from("/x"), sample()).unwrap();
        let model = m.model("top_gru").unwrap();
        assert_eq!(model.hlo[&10], "hlo/top_gru_b10.hlo.txt");
        assert_eq!(model.param_order[1], ("rnn".into(), "w".into()));
        assert_eq!(m.batch_buckets("top_gru").unwrap(), vec![1, 10]);
        assert_eq!(
            m.path("weights/top_gru.json"),
            PathBuf::from("/x/weights/top_gru.json")
        );
    }

    #[test]
    fn unknown_key_lists_options() {
        let m = Manifest::from_json(PathBuf::from("/x"), sample()).unwrap();
        let err = m.model("nope").unwrap_err().to_string();
        assert!(err.contains("top_gru"));
    }

    #[test]
    fn rejects_unknown_format() {
        let bad = sample().replace("hlo-text-v1", "hlo-proto-v9");
        assert!(Manifest::from_json(PathBuf::from("/x"), &bad).is_err());
    }
}
