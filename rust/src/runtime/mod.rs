//! PJRT runtime: load the AOT artifacts produced by `make artifacts` and
//! execute them on the request path — Python is never involved.
//!
//! * [`manifest`] — registry of compiled models (`artifacts/manifest.json`).
//! * [`engine`] — [`engine::PjrtModel`]: one compiled executable per
//!   (model, batch bucket), weights resident as device buffers, plus
//!   [`engine::Runtime`], the client + executable cache.
//!
//! The interchange is HLO **text** (see `python/compile/aot.py` for the
//! 64-bit-proto-id rationale) loaded via `HloModuleProto::from_text_file`
//! and compiled with the PJRT CPU client.

pub mod engine;
pub mod manifest;

pub use engine::{PjrtModel, Runtime};
pub use manifest::{Manifest, ManifestModel};
