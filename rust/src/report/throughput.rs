//! §5.2 throughput comparison: FPGA (analytical, from II) vs the batched
//! dense-pipeline engine (PJRT CPU — the stand-in for the paper's V100).
//!
//! The paper's claim has two parts: (a) the FPGA design's batch-1
//! throughput (4300–9700 ev/s for the QuickDraw LSTM) beats the GPU at
//! batch 1 (660 ev/s) by ~10×, and (b) the GPU catches up only at large
//! batch (7700 @ 10, ~30000 @ 100).  Part (a) reproduces analytically
//! from the scheduler's II; part (b) reproduces as a *relative batch
//! scaling* on the PJRT engine: batched executables amortize dispatch
//! exactly the way the GPU amortizes kernel launches.

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::coordinator::{
    BackendKind, BatcherConfig, EngineRunner, ServerConfig, ServingSpec,
    Session, ShardPolicy, ShardedConfig, ShardedServer, SourceConfig,
    TierMix, TierPolicy,
};
use crate::data::generators;
use crate::data::generators::Generator;
use crate::fixed::FixedSpec;
use crate::hls::latency::{self, Strategy};
use crate::hls::{paper, HlsConfig, ReuseFactor, RnnMode};
use crate::model::{zoo, Cell, Weights};
use crate::nn::{BackendCtx, BackendSpec, FloatEngine};
use crate::runtime::Runtime;
use crate::util::{json, timing};

use super::csv::CsvWriter;
use super::table::AsciiTable;

/// Measured/estimated throughput rows.
#[derive(Debug, Clone)]
pub struct ThroughputReport {
    /// (label, events/sec) — FPGA estimates then engine measurements.
    pub rows: Vec<(String, f64)>,
}

impl ThroughputReport {
    pub fn get(&self, label: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|(l, _)| l == label)
            .map(|&(_, v)| v)
    }
}

/// FPGA-side throughput band from the scheduler's II over the width band,
/// at the reuse column whose latency range matches the paper's quoted
/// 4300–9700 ev/s (R = (192, 128)).
pub fn fpga_band(cell: Cell) -> anyhow::Result<(f64, f64)> {
    let arch = zoo::arch("quickdraw", cell)?;
    let reuse = ReuseFactor::new(192, 128);
    let mut lo = f64::INFINITY;
    let mut hi: f64 = 0.0;
    for width in [latency::WIDTH_LO, latency::WIDTH_HI] {
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(width, 10.min(width - 1)),
            reuse,
        );
        cfg.strategy = Strategy::Resource;
        cfg.mode = RnnMode::Static;
        let t = latency::schedule(&arch, &cfg)?;
        lo = lo.min(t.throughput_hz);
        hi = hi.max(t.throughput_hz);
    }
    Ok((lo, hi))
}

/// Full comparison.  `artifacts` must exist for the engine measurements.
pub fn run(
    artifacts: &Path,
    events_per_batch_point: usize,
    out_dir: Option<&Path>,
) -> anyhow::Result<ThroughputReport> {
    let mut rows = Vec::new();

    let (lo, hi) = fpga_band(Cell::Lstm)?;
    rows.push(("fpga_model_min".to_string(), lo));
    rows.push(("fpga_model_max".to_string(), hi));

    // Engine (GPU-analog) side: quickdraw LSTM at batch 1 / 10 / 100.
    let runtime = Runtime::new(artifacts)?;
    for batch in [1usize, 10, 100] {
        let model = runtime.model("quickdraw_lstm", batch)?;
        let stride = model.seq_len * model.input_size;
        let xs = vec![0.1f32; batch * stride];
        let budget_ms =
            (events_per_batch_point as u64).clamp(200, 3_000);
        let stats = timing::bench_for(Duration::from_millis(budget_ms), || {
            model.run_batch(&xs, batch).expect("pjrt batch");
        });
        rows.push((
            format!("engine_batch{batch}"),
            stats.throughput(batch),
        ));
    }

    let p = &paper::QUICKDRAW_THROUGHPUT;
    let mut table = AsciiTable::new(
        "§5.2 throughput: QuickDraw LSTM, events/sec (paper values in parens)",
        &["source", "events/s", "paper"],
    );
    let paper_vals = [
        ("fpga_model_min", p.fpga_min),
        ("fpga_model_max", p.fpga_max),
        ("engine_batch1", p.gpu_batch1),
        ("engine_batch10", p.gpu_batch10),
        ("engine_batch100", p.gpu_batch100),
    ];
    for (label, paper_val) in paper_vals {
        let got = rows
            .iter()
            .find(|(l, _)| l == label)
            .map(|&(_, v)| v)
            .unwrap_or(0.0);
        table.row(vec![
            label.to_string(),
            format!("{got:.0}"),
            format!("{paper_val:.0}"),
        ]);
    }
    println!("{}", table.render());

    if let Some(dir) = out_dir {
        let mut csv = CsvWriter::new(
            dir.join("throughput_quickdraw.csv"),
            &["source", "events_per_sec"],
        );
        for (label, v) in &rows {
            csv.row(&[label.clone(), format!("{v:.1}")]);
        }
        println!("wrote {}", csv.finish()?.display());
    }
    Ok(ThroughputReport { rows })
}

// ------------------------------------------------------------- shard sweep

/// One measured serving configuration — a row of `BENCH_serving.json`.
#[derive(Debug, Clone)]
pub struct ServingBenchRow {
    /// Stable config label, e.g. `shards2_hash_w2`.
    pub config: String,
    pub shards: usize,
    pub policy: String,
    pub workers_per_shard: usize,
    /// Backend the row measures (`"fixed"` / `"float"`); for mixed
    /// sessions each backend tier contributes its own row, so per-tier
    /// latency stays comparable across PRs instead of blending.
    pub backend: String,
    /// Batcher size cap the row's shards served under (schema v3: the
    /// per-backend batcher columns — a row's latency is only comparable
    /// across PRs together with its batching policy).
    pub max_batch: usize,
    /// Batcher deadline (µs) the row's shards served under.
    pub max_wait_us: u64,
    /// Offered load, events/s (schema v5): the arrival rate the row was
    /// measured under — `samples_per_sec` is only meaningful relative
    /// to it (a saturation curve is rows sharing a config shape across
    /// offered rates).
    pub offered_hz: f64,
    pub samples_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub completed: u64,
    pub dropped: u64,
    /// Wire-level `SHED` rejections the load generator observed (schema
    /// v5; 0 for in-process sweeps, whose queue-full drops land in
    /// `dropped`).
    pub shed: u64,
}

/// Shards × policy serving sweep on the synthetic float engine (no
/// artifacts needed): every config serves the *same* top-tagging stream
/// at a saturating fixed-interval rate, so `samples_per_sec` measures
/// coordinator capacity, not source pacing.  This is the measurement
/// behind CI's `BENCH_serving.json` perf trajectory.
pub fn shard_sweep(
    shard_counts: &[usize],
    policies: &[ShardPolicy],
    workers_per_shard: usize,
    n_events: usize,
) -> anyhow::Result<Vec<ServingBenchRow>> {
    let arch = zoo::arch("top", Cell::Gru)?;
    let weights = Weights::synthetic(&arch, 0x5EED5);
    let mut rows = Vec::new();
    for &shards in shard_counts {
        for &policy in policies {
            let cfg = ShardedConfig {
                shards,
                policy,
                tier_mix: TierMix::single(),
                shard_backends: Vec::new(),
                shard_batchers: Vec::new(),
                server: ServerConfig {
                    workers: workers_per_shard,
                    queue_capacity: 8192,
                    batcher: BatcherConfig {
                        max_batch: 32,
                        max_wait: Duration::from_micros(200),
                    },
                    source: SourceConfig {
                        // Saturating arrivals: push the coordinator, let
                        // the bounded queues shed what it can't serve.
                        rate_hz: 2_000_000.0,
                        poisson: false,
                        n_events,
                    },
                },
            };
            let weights = weights.clone();
            let generator = generators::for_benchmark("top", 0xBEEF)?;
            // Batcher columns come from the measured config itself, so
            // tuning the sweep can never desynchronize the artifact.
            let batcher = cfg.server.batcher;
            let offered_hz = cfg.server.source.rate_hz;
            let report = ShardedServer::run(cfg, generator, move |_shard| {
                let engine = FloatEngine::new(&weights)?;
                Ok(Box::new(EngineRunner::new(Box::new(engine), 32))
                    as Box<dyn crate::coordinator::BatchRunner>)
            })?;
            rows.push(ServingBenchRow {
                config: format!(
                    "shards{shards}_{}_w{workers_per_shard}",
                    policy.name()
                ),
                shards,
                policy: policy.name().to_string(),
                workers_per_shard,
                backend: "float".to_string(),
                max_batch: batcher.max_batch,
                max_wait_us: batcher.max_wait.as_micros() as u64,
                offered_hz,
                samples_per_sec: report.merged.throughput_hz,
                p50_us: report.merged.p50_latency_us,
                p99_us: report.merged.p99_latency_us,
                completed: report.merged.completed,
                dropped: report.merged.dropped,
                shed: 0,
            });
        }
    }
    Ok(rows)
}

/// Mixed-backend serving sweep: single-backend baselines (fixed, float —
/// each serving the whole stream alone) plus one heterogeneous session
/// (2 shards, fixed trigger tier at 90 % / float offline tier at 10 %,
/// model-key routing) reported *per backend* from the roll-up's tier
/// split.  Synthetic weights, saturating arrivals — same measurement
/// discipline as [`shard_sweep`]; the rows land in `BENCH_serving.json`
/// so CI tracks per-tier latency across PRs.
pub fn mixed_backend_sweep(
    workers_per_shard: usize,
    n_events: usize,
) -> anyhow::Result<Vec<ServingBenchRow>> {
    let arch = zoo::arch("top", Cell::Gru)?;
    let weights = Weights::synthetic(&arch, 0x5EED5);
    let fixed_spec = FixedSpec::new(16, 6);
    let server = ServerConfig {
        workers: workers_per_shard,
        queue_capacity: 8192,
        batcher: BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(200),
        },
        source: SourceConfig {
            rate_hz: 2_000_000.0,
            poisson: false,
            n_events,
        },
    };
    let mut rows = Vec::new();

    // Single-backend baselines.
    for name in ["fixed", "float"] {
        let spec = BackendSpec::parse(name)?;
        let cfg = ShardedConfig {
            shards: 1,
            policy: ShardPolicy::ModelKey,
            tier_mix: TierMix::single(),
            shard_backends: vec![name.to_string()],
            shard_batchers: Vec::new(),
            server,
        };
        let generator = generators::for_benchmark("top", 0xBEEF)?;
        let weights = weights.clone();
        let report = ShardedServer::run(cfg, generator, move |_shard| {
            let engine = spec.build(&BackendCtx {
                weights: &weights,
                fixed_spec,
                parallelism: 1,
            })?;
            Ok(Box::new(EngineRunner::new(engine, 32))
                as Box<dyn crate::coordinator::BatchRunner>)
        })?;
        rows.push(ServingBenchRow {
            config: format!("single_{name}_w{workers_per_shard}"),
            shards: 1,
            policy: "model-key".to_string(),
            workers_per_shard,
            backend: name.to_string(),
            max_batch: server.batcher.max_batch,
            max_wait_us: server.batcher.max_wait.as_micros() as u64,
            offered_hz: server.source.rate_hz,
            samples_per_sec: report.merged.throughput_hz,
            p50_us: report.merged.p50_latency_us,
            p99_us: report.merged.p99_latency_us,
            completed: report.merged.completed,
            dropped: report.merged.dropped,
            shed: 0,
        });
    }

    // Heterogeneous session: 90 % trigger-tier → fixed, 10 % offline-tier
    // → float; one row per backend from the per-tier metrics split.
    let specs = [BackendSpec::parse("fixed")?, BackendSpec::parse("float")?];
    let cfg = ShardedConfig {
        shards: 2,
        policy: ShardPolicy::ModelKey,
        tier_mix: TierMix::new(&[0.9, 0.1], 0x7135)?,
        shard_backends: specs.iter().map(|s| s.name().to_string()).collect(),
        shard_batchers: Vec::new(),
        server,
    };
    let generator = generators::for_benchmark("top", 0xBEEF)?;
    let factory_weights = weights.clone();
    let report = ShardedServer::run(cfg, generator, move |shard| {
        let engine = specs[shard].build(&BackendCtx {
            weights: &factory_weights,
            fixed_spec,
            parallelism: 1,
        })?;
        Ok(Box::new(EngineRunner::new(engine, 32))
            as Box<dyn crate::coordinator::BatchRunner>)
    })?;
    for tier in &report.per_backend {
        rows.push(ServingBenchRow {
            config: format!("mixed90_10_{}_w{workers_per_shard}", tier.backend),
            shards: 2,
            policy: "model-key".to_string(),
            workers_per_shard,
            backend: tier.backend.clone(),
            max_batch: tier.batcher.max_batch,
            max_wait_us: tier.batcher.max_wait.as_micros() as u64,
            offered_hz: server.source.rate_hz,
            samples_per_sec: tier.report.throughput_hz,
            p50_us: tier.report.p50_latency_us,
            p99_us: tier.report.p99_latency_us,
            completed: tier.report.completed,
            dropped: tier.report.dropped,
            shed: 0,
        });
    }
    Ok(rows)
}

/// Tier-aware batching sweep: the heterogeneous fixed+float session of
/// [`mixed_backend_sweep`], but with each shard under its *tier's*
/// batching policy ([`TierPolicy::for_backends`]): the fixed trigger
/// tier pinned at strict batch-1 / zero-wait, the float offline tier
/// batching up to 64 with a 2 ms deadline.  One row per backend, each
/// carrying its batcher columns (`max_batch`, `max_wait_us` — the
/// schema-v3 addition), so CI tracks the trigger tier's batch-1 latency
/// and the offline tier's deep-batch throughput as separate
/// trajectories.  Same measurement discipline as [`shard_sweep`]:
/// synthetic weights, saturating fixed-interval arrivals.
pub fn tier_batch_sweep(
    workers_per_shard: usize,
    n_events: usize,
) -> anyhow::Result<Vec<ServingBenchRow>> {
    let arch = zoo::arch("top", Cell::Gru)?;
    let weights = Weights::synthetic(&arch, 0x5EED5);
    let fixed_spec = FixedSpec::new(16, 6);
    let specs = [BackendSpec::parse("fixed")?, BackendSpec::parse("float")?];
    let backends: Vec<String> =
        specs.iter().map(|s| s.name().to_string()).collect();
    let policy = TierPolicy::for_backends(&backends);
    let runner_caps: Vec<usize> =
        policy.batchers().iter().map(|b| b.max_batch).collect();
    let cfg = ShardedConfig {
        shards: 2,
        policy: ShardPolicy::ModelKey,
        tier_mix: TierMix::new(&[0.9, 0.1], 0x7135)?,
        shard_backends: backends,
        shard_batchers: policy.batchers(),
        server: ServerConfig {
            workers: workers_per_shard,
            queue_capacity: 8192,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_micros(200),
            },
            source: SourceConfig {
                rate_hz: 2_000_000.0,
                poisson: false,
                n_events,
            },
        },
    };
    let generator = generators::for_benchmark("top", 0xBEEF)?;
    let factory_weights = weights.clone();
    let report = ShardedServer::run(cfg, generator, move |shard| {
        let engine = specs[shard].build(&BackendCtx {
            weights: &factory_weights,
            fixed_spec,
            parallelism: 1,
        })?;
        Ok(Box::new(EngineRunner::new(engine, runner_caps[shard]))
            as Box<dyn crate::coordinator::BatchRunner>)
    })?;
    let mut rows = Vec::new();
    for tier in &report.per_backend {
        rows.push(ServingBenchRow {
            config: format!(
                "tier_batch_{}_w{workers_per_shard}",
                tier.backend
            ),
            shards: 2,
            policy: "model-key".to_string(),
            workers_per_shard,
            backend: tier.backend.clone(),
            max_batch: tier.batcher.max_batch,
            max_wait_us: tier.batcher.max_wait.as_micros() as u64,
            offered_hz: 2_000_000.0,
            samples_per_sec: tier.report.throughput_hz,
            p50_us: tier.report.p50_latency_us,
            p99_us: tier.report.p99_latency_us,
            completed: tier.report.completed,
            dropped: tier.report.dropped,
            shed: 0,
        });
    }
    Ok(rows)
}

/// Session-API overhead row pair: the same saturating top-GRU stream
/// served (a) through the replay wrapper — `session_replay_*`, the
/// `ShardedServer::run` path, where `source::run_with` drives
/// `Session::submit` internally with completions off — and (b) through
/// the public live [`Session`] API — `session_submit_*`, an external
/// submitter calling `submit_event` with the completion channel enabled.
/// The delta between the two rows is the cost of the request-driven
/// path (router lock, id stamping, completion forwarding); CI tracks it
/// in `BENCH_serving.json` (schema v4) so the session API stays on the
/// serving fast path.  Same measurement discipline as [`shard_sweep`]:
/// synthetic weights, saturating fixed-interval arrivals.
pub fn session_submit_sweep(
    workers_per_shard: usize,
    n_events: usize,
) -> anyhow::Result<Vec<ServingBenchRow>> {
    let arch = zoo::arch("top", Cell::Gru)?;
    let weights = Weights::synthetic(&arch, 0x5EED5);
    let batcher = BatcherConfig {
        max_batch: 32,
        max_wait: Duration::from_micros(200),
    };
    let source = SourceConfig {
        rate_hz: 2_000_000.0,
        poisson: false,
        n_events,
    };
    let row = |config: String, merged: &crate::coordinator::ServerReport| ServingBenchRow {
        config,
        shards: 1,
        policy: "hash".to_string(),
        workers_per_shard,
        backend: "float".to_string(),
        max_batch: batcher.max_batch,
        max_wait_us: batcher.max_wait.as_micros() as u64,
        offered_hz: source.rate_hz,
        samples_per_sec: merged.throughput_hz,
        p50_us: merged.p50_latency_us,
        p99_us: merged.p99_latency_us,
        completed: merged.completed,
        dropped: merged.dropped,
        shed: 0,
    };
    let mut rows = Vec::new();

    // (a) Replay wrapper: the classic run-to-completion path.
    let cfg = ShardedConfig {
        shards: 1,
        policy: ShardPolicy::HashId,
        tier_mix: TierMix::single(),
        shard_backends: Vec::new(),
        shard_batchers: Vec::new(),
        server: ServerConfig {
            workers: workers_per_shard,
            queue_capacity: 8192,
            batcher,
            source,
        },
    };
    let replay_weights = weights.clone();
    let generator = generators::for_benchmark("top", 0xBEEF)?;
    let report = ShardedServer::run(cfg, generator, move |_shard| {
        let engine = FloatEngine::new(&replay_weights)?;
        Ok(Box::new(EngineRunner::new(Box::new(engine), 32))
            as Box<dyn crate::coordinator::BatchRunner>)
    })?;
    rows.push(row(
        format!("session_replay_w{workers_per_shard}"),
        &report.merged,
    ));

    // (b) Live session: an external submitter pushing the identical
    // generated stream through the public API, completions on.
    let spec = ServingSpec::default()
        .with_engine(BackendKind::Float)
        .with_workers(workers_per_shard)
        .with_batcher(batcher.max_batch, batcher.max_wait)
        .with_queue_capacity(8192)
        .with_source(source);
    let live_weights = weights.clone();
    let session = Session::start(&spec, move |_shard| {
        let engine = FloatEngine::new(&live_weights)?;
        Ok(Box::new(EngineRunner::new(Box::new(engine), 32))
            as Box<dyn crate::coordinator::BatchRunner>)
    })?;
    let mut generator = generators::for_benchmark("top", 0xBEEF)?;
    for _ in 0..n_events {
        let event = generator.generate();
        // A full queue is the session's typed backpressure; the drop is
        // counted in the report exactly like replay overflow.
        let _ = session.submit_event(event.features, event.label);
    }
    // The completion channel is part of the measured path; consume it
    // before closing out.
    let _ = session.drain();
    let report = session.shutdown()?;
    rows.push(row(
        format!("session_submit_w{workers_per_shard}"),
        &report.merged,
    ));
    Ok(rows)
}

/// Network saturation curve: the heterogeneous fixed+float session of
/// [`mixed_backend_sweep`] served over a real TCP listener
/// ([`Session::serve_listener`]) and driven by the open-loop
/// [`loadgen`](crate::ingest::loadgen) harness at a ladder of offered
/// rates (20 k / 100 k / 400 k ev/s) — under-, near-, and
/// over-saturation.  Each load point contributes:
///
/// * one merged row (`loadgen_r{rate}k_merged_w*`, backend `mixed`)
///   carrying the *client-observed* round-trip p50/p99, the achieved
///   completion rate, and the wire-level `shed` count — the saturation
///   curve proper;
/// * one row per backend tier (`loadgen_r{rate}k_{fixed,float}_w*`)
///   carrying the server-side per-tier p50/p99 under that offered load
///   — per-tier latency **under overload**, the quantity the paper's
///   trigger budget is about.
///
/// Every point asserts the end-to-end accounting identity
/// (`generated == completed + shed + closed + lost`) before reporting —
/// the first measurement where the identity crosses a process boundary.
pub fn loadgen_sweep(
    workers_per_shard: usize,
    events_per_point: usize,
) -> anyhow::Result<Vec<ServingBenchRow>> {
    use crate::ingest::loadgen::{run_load, LoadConfig, Profile};

    let arch = zoo::arch("top", Cell::Gru)?;
    let weights = Weights::synthetic(&arch, 0x5EED5);
    let fixed_spec = FixedSpec::new(16, 6);
    let feature_len = arch.seq_len * arch.input_size;
    let mut rows = Vec::new();

    for &offered_hz in &[20_000.0f64, 100_000.0, 400_000.0] {
        let spec = ServingSpec::default()
            .with_backends(vec![BackendKind::Fixed, BackendKind::Float])
            .with_shards(2)
            .with_shard_policy(ShardPolicy::ModelKey)
            .with_tier_mix(TierMix::new(&[0.9, 0.1], 0x7135)?)
            .with_workers(workers_per_shard)
            .with_queue_capacity(8192)
            .with_listener("127.0.0.1:0".parse()?);
        let plan = spec.build()?;
        let caps: Vec<usize> =
            (0..2).map(|shard| plan.runner_cap(shard)).collect();
        let kinds: Vec<BackendKind> =
            (0..2).map(|shard| plan.kind_for(shard)).collect();
        let factory_weights = weights.clone();
        let session = Session::start_plan(plan, move |shard| {
            let engine = kinds[shard].spec().build(&BackendCtx {
                weights: &factory_weights,
                fixed_spec,
                parallelism: 1,
            })?;
            Ok(Box::new(EngineRunner::new(engine, caps[shard]))
                as Box<dyn crate::coordinator::BatchRunner>)
        })?;
        let server = session.serve_listener()?;

        let mut load = LoadConfig::new(server.local_addr());
        load.clients = 1000;
        load.connections = 4;
        load.rate_hz = offered_hz;
        load.events = events_per_point;
        load.profile = Profile::Poisson;
        load.feature_len = feature_len;
        let report = run_load(&load)?;
        report.check_identity()?;
        let net = server.shutdown()?;

        let rate_k = (offered_hz / 1000.0) as u64;
        rows.push(ServingBenchRow {
            config: format!("loadgen_r{rate_k}k_merged_w{workers_per_shard}"),
            shards: 2,
            policy: "model-key".to_string(),
            workers_per_shard,
            backend: "mixed".to_string(),
            max_batch: 0,
            max_wait_us: 0,
            offered_hz,
            // Client-observed numbers: achieved rate and round-trip
            // latency over the socket.
            samples_per_sec: report.completed_hz(),
            p50_us: report.latency.quantile_us(0.5),
            p99_us: report.latency.quantile_us(0.99),
            completed: report.completed,
            dropped: net.serving.merged.dropped,
            shed: report.shed,
        });
        for tier in &net.serving.per_backend {
            rows.push(ServingBenchRow {
                config: format!(
                    "loadgen_r{rate_k}k_{}_w{workers_per_shard}",
                    tier.backend
                ),
                shards: 2,
                policy: "model-key".to_string(),
                workers_per_shard,
                backend: tier.backend.clone(),
                max_batch: tier.batcher.max_batch,
                max_wait_us: tier.batcher.max_wait.as_micros() as u64,
                offered_hz,
                samples_per_sec: tier.report.throughput_hz,
                p50_us: tier.report.p50_latency_us,
                p99_us: tier.report.p99_latency_us,
                completed: tier.report.completed,
                dropped: tier.report.dropped,
                // Wire-level shed is a client-side observation, only
                // available merged; a per-tier row's queue-full drops
                // are already in `dropped` (see the field docs).
                shed: 0,
            });
        }
    }
    Ok(rows)
}

/// Emit the sweep as machine-readable JSON (the CI bench artifact).
pub fn write_bench_json(
    path: &Path,
    rows: &[ServingBenchRow],
) -> anyhow::Result<PathBuf> {
    let doc = json::obj(vec![
        ("bench", json::s("serving")),
        // v2: every row carries a `backend` field (per-tier rows for the
        // mixed-backend sweep; "float" for the homogeneous shard sweep).
        // v3: per-backend batcher columns (`max_batch`, `max_wait_us`)
        // plus the tier-aware `tier_batch_*` rows, so per-tier latency
        // trajectories carry the batching policy they were measured
        // under.
        // v4: the `session_replay_*` / `session_submit_*` row pair from
        // the session-API overhead sweep, so the live request path is a
        // tracked trajectory next to the replay path it must keep up
        // with.
        // v5: `offered_hz` + `shed` on every row, plus the network
        // saturation-curve rows (`loadgen_r*`) from the socket-level
        // loadgen sweep — per-tier p99 under overload becomes a tracked
        // trajectory, measured across a real process boundary.
        ("schema_version", json::num(5.0)),
        (
            "rows",
            json::arr(
                rows.iter()
                    .map(|r| {
                        json::obj(vec![
                            ("config", json::s(&r.config)),
                            ("shards", json::num(r.shards as f64)),
                            ("policy", json::s(&r.policy)),
                            ("backend", json::s(&r.backend)),
                            ("max_batch", json::num(r.max_batch as f64)),
                            ("max_wait_us", json::num(r.max_wait_us as f64)),
                            (
                                "workers_per_shard",
                                json::num(r.workers_per_shard as f64),
                            ),
                            ("offered_hz", json::num(r.offered_hz)),
                            (
                                "samples_per_sec",
                                json::num(r.samples_per_sec),
                            ),
                            ("p50_us", json::num(r.p50_us)),
                            ("p99_us", json::num(r.p99_us)),
                            ("completed", json::num(r.completed as f64)),
                            ("dropped", json::num(r.dropped as f64)),
                            ("shed", json::num(r.shed as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut text = doc.to_json();
    text.push('\n');
    std::fs::write(path, text)?;
    Ok(path.to_path_buf())
}

/// Shape checks for EXPERIMENTS.md: batch scaling must be monotone with
/// measurable amortization.  The paper's GPU shows ~45× from batch 1 to
/// 100 because GPU batch-1 is *launch-bound*; the PJRT CPU analog is
/// already compute-bound at batch 1, so its amortization is modest —
/// we require monotone scaling and ≥1.15× (documented substitution
/// limit in EXPERIMENTS.md §Deviations).
pub fn shape_check(report: &ThroughputReport) -> anyhow::Result<()> {
    let b1 = report
        .get("engine_batch1")
        .ok_or_else(|| anyhow::anyhow!("no batch-1 row"))?;
    let b10 = report
        .get("engine_batch10")
        .ok_or_else(|| anyhow::anyhow!("no batch-10 row"))?;
    let b100 = report
        .get("engine_batch100")
        .ok_or_else(|| anyhow::anyhow!("no batch-100 row"))?;
    anyhow::ensure!(
        b10 > b1 && b100 > b10,
        "batch scaling not monotone: {b1:.0} / {b10:.0} / {b100:.0}"
    );
    anyhow::ensure!(
        b100 / b1 >= 1.15,
        "batch-100 amortization only {:.2}x",
        b100 / b1
    );
    let fpga_min = report.get("fpga_model_min").unwrap_or(0.0);
    let fpga_max = report.get("fpga_model_max").unwrap_or(0.0);
    anyhow::ensure!(
        fpga_min > 3_000.0 && fpga_max < 12_000.0,
        "FPGA band {fpga_min:.0}-{fpga_max:.0} out of the paper's regime"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The analytical FPGA band must straddle the paper's 4300–9700 ev/s.
    #[test]
    fn fpga_band_matches_paper_regime() {
        let (lo, hi) = fpga_band(Cell::Lstm).unwrap();
        assert!(lo < hi);
        // paper: 4300 (max width) to 9700 (min width)
        assert!((lo - 4_300.0).abs() / 4_300.0 < 0.25, "lo {lo:.0}");
        assert!((hi - 9_700.0).abs() / 9_700.0 < 0.25, "hi {hi:.0}");
    }

    /// Reduced shard sweep end to end: every config accounts for every
    /// event, and the JSON artifact round-trips through our own parser.
    #[test]
    fn shard_sweep_rows_and_json_roundtrip() {
        let rows = shard_sweep(&[1, 2], &[ShardPolicy::HashId], 1, 400)
            .unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.completed + r.dropped, 400, "{}", r.config);
            assert!(r.samples_per_sec > 0.0, "{}", r.config);
            assert!(r.p50_us <= r.p99_us, "{}", r.config);
        }
        assert_eq!(rows[0].config, "shards1_hash_w1");
        assert_eq!(rows[1].config, "shards2_hash_w1");
        assert_eq!(rows[0].backend, "float");

        let dir = std::env::temp_dir().join(format!(
            "rnnhls-bench-json-{}",
            std::process::id()
        ));
        let path = dir.join("BENCH_serving.json");
        write_bench_json(&path, &rows).unwrap();
        let parsed =
            json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.req("bench").unwrap().as_str().unwrap(), "serving");
        assert_eq!(
            parsed.req("schema_version").unwrap().as_usize().unwrap(),
            5
        );
        let json_rows = parsed.req("rows").unwrap().as_array().unwrap();
        assert_eq!(json_rows.len(), 2);
        assert_eq!(
            json_rows[1].req("shards").unwrap().as_usize().unwrap(),
            2
        );
        assert_eq!(
            json_rows[0].req("backend").unwrap().as_str().unwrap(),
            "float"
        );
        // v3: batcher columns ride along on every row.
        assert_eq!(
            json_rows[0].req("max_batch").unwrap().as_usize().unwrap(),
            32
        );
        assert_eq!(
            json_rows[0].req("max_wait_us").unwrap().as_usize().unwrap(),
            200
        );
        assert!(json_rows[0].req("samples_per_sec").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_dir_all(dir).ok();
    }

    /// Reduced mixed-backend sweep: per-backend rows exist, single runs
    /// see the whole stream, and the mixed rows exactly partition it
    /// with the trigger tier taking the configured bulk.
    #[test]
    fn mixed_backend_sweep_emits_per_backend_rows() {
        let rows = mixed_backend_sweep(1, 400).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].config, "single_fixed_w1");
        assert_eq!(rows[0].backend, "fixed");
        assert_eq!(rows[1].config, "single_float_w1");
        assert_eq!(rows[1].backend, "float");
        for r in &rows[..2] {
            assert_eq!(r.completed + r.dropped, 400, "{}", r.config);
            assert!(r.samples_per_sec > 0.0, "{}", r.config);
        }
        let mixed = &rows[2..];
        assert!(mixed.iter().all(|r| r.config.starts_with("mixed90_10_")));
        let routed: u64 = mixed.iter().map(|r| r.completed + r.dropped).sum();
        assert_eq!(routed, 400, "mixed tiers must partition the stream");
        let fixed = mixed.iter().find(|r| r.backend == "fixed").unwrap();
        let float = mixed.iter().find(|r| r.backend == "float").unwrap();
        assert!(
            fixed.completed + fixed.dropped > float.completed + float.dropped,
            "90/10 mix: trigger tier must dominate"
        );
    }

    /// Reduced tier-aware batching sweep: one row per backend, the
    /// trigger tier pinned at batch-1/zero-wait, the offline tier deep,
    /// and the two tiers exactly partitioning the stream.
    #[test]
    fn tier_batch_sweep_pins_trigger_and_offline_policies() {
        let rows = tier_batch_sweep(1, 400).unwrap();
        assert_eq!(rows.len(), 2);
        let fixed = rows.iter().find(|r| r.backend == "fixed").unwrap();
        assert_eq!(fixed.config, "tier_batch_fixed_w1");
        assert_eq!(fixed.max_batch, 1, "trigger tier must be batch-1");
        assert_eq!(fixed.max_wait_us, 0, "trigger tier must never wait");
        let float = rows.iter().find(|r| r.backend == "float").unwrap();
        assert_eq!(float.config, "tier_batch_float_w1");
        assert_eq!(float.max_batch, 64, "offline tier must batch deep");
        assert_eq!(float.max_wait_us, 2000);
        let routed: u64 = rows.iter().map(|r| r.completed + r.dropped).sum();
        assert_eq!(routed, 400, "tiers must partition the stream");
        // 90/10 mix: the trigger tier dominates admission.
        assert!(
            fixed.completed + fixed.dropped > float.completed + float.dropped
        );
    }

    /// Reduced session-overhead sweep: the replay/live row pair exists,
    /// both paths account for every event, and the live path (public
    /// submit + completion channel) actually served the stream.
    #[test]
    fn session_submit_sweep_emits_replay_and_live_rows() {
        let rows = session_submit_sweep(1, 400).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].config, "session_replay_w1");
        assert_eq!(rows[1].config, "session_submit_w1");
        for r in &rows {
            assert_eq!(r.completed + r.dropped, 400, "{}", r.config);
            assert!(r.completed > 0, "{}", r.config);
            assert!(r.samples_per_sec > 0.0, "{}", r.config);
            assert_eq!(r.backend, "float", "{}", r.config);
            assert_eq!(r.max_batch, 32, "{}", r.config);
        }
    }

    #[test]
    fn shape_check_logic() {
        let good = ThroughputReport {
            rows: vec![
                ("fpga_model_min".into(), 4500.0),
                ("fpga_model_max".into(), 9500.0),
                ("engine_batch1".into(), 1600.0),
                ("engine_batch10".into(), 1800.0),
                ("engine_batch100".into(), 2200.0),
            ],
        };
        shape_check(&good).unwrap();
        let bad = ThroughputReport {
            rows: vec![
                ("fpga_model_min".into(), 4500.0),
                ("fpga_model_max".into(), 9500.0),
                ("engine_batch1".into(), 700.0),
                ("engine_batch10".into(), 500.0),
                ("engine_batch100".into(), 400.0),
            ],
        };
        assert!(shape_check(&bad).is_err());
    }
}
