//! §5.2 throughput comparison: FPGA (analytical, from II) vs the batched
//! dense-pipeline engine (PJRT CPU — the stand-in for the paper's V100).
//!
//! The paper's claim has two parts: (a) the FPGA design's batch-1
//! throughput (4300–9700 ev/s for the QuickDraw LSTM) beats the GPU at
//! batch 1 (660 ev/s) by ~10×, and (b) the GPU catches up only at large
//! batch (7700 @ 10, ~30000 @ 100).  Part (a) reproduces analytically
//! from the scheduler's II; part (b) reproduces as a *relative batch
//! scaling* on the PJRT engine: batched executables amortize dispatch
//! exactly the way the GPU amortizes kernel launches.

use std::path::Path;
use std::time::Duration;

use crate::fixed::FixedSpec;
use crate::hls::latency::{self, Strategy};
use crate::hls::{paper, HlsConfig, ReuseFactor, RnnMode};
use crate::model::{zoo, Cell};
use crate::runtime::Runtime;
use crate::util::timing;

use super::csv::CsvWriter;
use super::table::AsciiTable;

/// Measured/estimated throughput rows.
#[derive(Debug, Clone)]
pub struct ThroughputReport {
    /// (label, events/sec) — FPGA estimates then engine measurements.
    pub rows: Vec<(String, f64)>,
}

impl ThroughputReport {
    pub fn get(&self, label: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|(l, _)| l == label)
            .map(|&(_, v)| v)
    }
}

/// FPGA-side throughput band from the scheduler's II over the width band,
/// at the reuse column whose latency range matches the paper's quoted
/// 4300–9700 ev/s (R = (192, 128)).
pub fn fpga_band(cell: Cell) -> anyhow::Result<(f64, f64)> {
    let arch = zoo::arch("quickdraw", cell)?;
    let reuse = ReuseFactor::new(192, 128);
    let mut lo = f64::INFINITY;
    let mut hi: f64 = 0.0;
    for width in [latency::WIDTH_LO, latency::WIDTH_HI] {
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(width, 10.min(width - 1)),
            reuse,
        );
        cfg.strategy = Strategy::Resource;
        cfg.mode = RnnMode::Static;
        let t = latency::schedule(&arch, &cfg)?;
        lo = lo.min(t.throughput_hz);
        hi = hi.max(t.throughput_hz);
    }
    Ok((lo, hi))
}

/// Full comparison.  `artifacts` must exist for the engine measurements.
pub fn run(
    artifacts: &Path,
    events_per_batch_point: usize,
    out_dir: Option<&Path>,
) -> anyhow::Result<ThroughputReport> {
    let mut rows = Vec::new();

    let (lo, hi) = fpga_band(Cell::Lstm)?;
    rows.push(("fpga_model_min".to_string(), lo));
    rows.push(("fpga_model_max".to_string(), hi));

    // Engine (GPU-analog) side: quickdraw LSTM at batch 1 / 10 / 100.
    let runtime = Runtime::new(artifacts)?;
    for batch in [1usize, 10, 100] {
        let model = runtime.model("quickdraw_lstm", batch)?;
        let stride = model.seq_len * model.input_size;
        let xs = vec![0.1f32; batch * stride];
        let budget_ms =
            (events_per_batch_point as u64).clamp(200, 3_000);
        let stats = timing::bench_for(Duration::from_millis(budget_ms), || {
            model.run_batch(&xs, batch).expect("pjrt batch");
        });
        rows.push((
            format!("engine_batch{batch}"),
            stats.throughput(batch),
        ));
    }

    let p = &paper::QUICKDRAW_THROUGHPUT;
    let mut table = AsciiTable::new(
        "§5.2 throughput: QuickDraw LSTM, events/sec (paper values in parens)",
        &["source", "events/s", "paper"],
    );
    let paper_vals = [
        ("fpga_model_min", p.fpga_min),
        ("fpga_model_max", p.fpga_max),
        ("engine_batch1", p.gpu_batch1),
        ("engine_batch10", p.gpu_batch10),
        ("engine_batch100", p.gpu_batch100),
    ];
    for (label, paper_val) in paper_vals {
        let got = rows
            .iter()
            .find(|(l, _)| l == label)
            .map(|&(_, v)| v)
            .unwrap_or(0.0);
        table.row(vec![
            label.to_string(),
            format!("{got:.0}"),
            format!("{paper_val:.0}"),
        ]);
    }
    println!("{}", table.render());

    if let Some(dir) = out_dir {
        let mut csv = CsvWriter::new(
            dir.join("throughput_quickdraw.csv"),
            &["source", "events_per_sec"],
        );
        for (label, v) in &rows {
            csv.row(&[label.clone(), format!("{v:.1}")]);
        }
        println!("wrote {}", csv.finish()?.display());
    }
    Ok(ThroughputReport { rows })
}

/// Shape checks for EXPERIMENTS.md: batch scaling must be monotone with
/// measurable amortization.  The paper's GPU shows ~45× from batch 1 to
/// 100 because GPU batch-1 is *launch-bound*; the PJRT CPU analog is
/// already compute-bound at batch 1, so its amortization is modest —
/// we require monotone scaling and ≥1.15× (documented substitution
/// limit in EXPERIMENTS.md §Deviations).
pub fn shape_check(report: &ThroughputReport) -> anyhow::Result<()> {
    let b1 = report
        .get("engine_batch1")
        .ok_or_else(|| anyhow::anyhow!("no batch-1 row"))?;
    let b10 = report
        .get("engine_batch10")
        .ok_or_else(|| anyhow::anyhow!("no batch-10 row"))?;
    let b100 = report
        .get("engine_batch100")
        .ok_or_else(|| anyhow::anyhow!("no batch-100 row"))?;
    anyhow::ensure!(
        b10 > b1 && b100 > b10,
        "batch scaling not monotone: {b1:.0} / {b10:.0} / {b100:.0}"
    );
    anyhow::ensure!(
        b100 / b1 >= 1.15,
        "batch-100 amortization only {:.2}x",
        b100 / b1
    );
    let fpga_min = report.get("fpga_model_min").unwrap_or(0.0);
    let fpga_max = report.get("fpga_model_max").unwrap_or(0.0);
    anyhow::ensure!(
        fpga_min > 3_000.0 && fpga_max < 12_000.0,
        "FPGA band {fpga_min:.0}-{fpga_max:.0} out of the paper's regime"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The analytical FPGA band must straddle the paper's 4300–9700 ev/s.
    #[test]
    fn fpga_band_matches_paper_regime() {
        let (lo, hi) = fpga_band(Cell::Lstm).unwrap();
        assert!(lo < hi);
        // paper: 4300 (max width) to 9700 (min width)
        assert!((lo - 4_300.0).abs() / 4_300.0 < 0.25, "lo {lo:.0}");
        assert!((hi - 9_700.0).abs() / 9_700.0 < 0.25, "hi {hi:.0}");
    }

    #[test]
    fn shape_check_logic() {
        let good = ThroughputReport {
            rows: vec![
                ("fpga_model_min".into(), 4500.0),
                ("fpga_model_max".into(), 9500.0),
                ("engine_batch1".into(), 1600.0),
                ("engine_batch10".into(), 1800.0),
                ("engine_batch100".into(), 2200.0),
            ],
        };
        shape_check(&good).unwrap();
        let bad = ThroughputReport {
            rows: vec![
                ("fpga_model_min".into(), 4500.0),
                ("fpga_model_max".into(), 9500.0),
                ("engine_batch1".into(), 700.0),
                ("engine_batch10".into(), 500.0),
                ("engine_batch100".into(), 400.0),
            ],
        };
        assert!(shape_check(&bad).is_err());
    }
}
