//! Output surfaces for the design-space explorer (`hls::explore`):
//! ASCII Pareto-front table, CSV, and the `BENCH_explore.json` CI
//! artifact.
//!
//! All three surfaces emit front rows in [`Candidate::sort_key`] order
//! and the JSON writer goes through the deterministic `util::json`
//! printer, so repeated runs over the same grid are byte-identical —
//! `ci.sh --bench-smoke` relies on that to diff artifacts across
//! commits.

use std::path::{Path, PathBuf};

use crate::hls::explore::{Candidate, ExploreResult};
use crate::util::json::{self, Value};

use super::csv::CsvWriter;
use super::table::{f, AsciiTable};

/// The per-row fields every machine-readable surface carries, in column
/// order.
pub const ROW_FIELDS: [&str; 18] = [
    "name",
    "model",
    "width",
    "integer",
    "reuse_kernel",
    "reuse_recurrent",
    "strategy",
    "mode",
    "clock_mhz",
    "latency_ns",
    "ii_ns",
    "dsp",
    "lut",
    "ff",
    "bram_18k",
    "auc",
    "backend",
    "tier",
];

fn auc_cell(c: &Candidate) -> String {
    match c.auc {
        Some(auc) => format!("{auc:.4}"),
        None => "-".to_string(),
    }
}

/// Render the Pareto front as an ASCII table.
pub fn render(result: &ExploreResult) -> String {
    let mut table = AsciiTable::new(
        format!(
            "Design-space Pareto front on {} ({} evaluated, {} admitted, \
             {} on front)",
            result.device.name,
            result.candidates.len(),
            result.admitted.len(),
            result.front.len()
        ),
        &[
            "model", "type", "R", "strategy", "mode", "clk", "latency µs",
            "II µs", "DSP", "LUT", "FF", "BRAM", "AUC", "tier",
        ],
    );
    for c in result.front_rows() {
        let bc = c.backend_candidate();
        table.row(vec![
            c.arch_key.clone(),
            format!("ap_fixed{}", c.config.spec.label()),
            c.config.reuse.label(),
            c.config.strategy.label().to_string(),
            c.config.mode.label().to_string(),
            format!("{:.0}", c.config.clock_mhz),
            f(c.timing.latency_us, 3),
            f(c.timing.ii_us, 3),
            c.resources.dsp.to_string(),
            c.resources.lut.to_string(),
            c.resources.ff.to_string(),
            c.resources.bram_18k.to_string(),
            auc_cell(c),
            bc.tier.name().to_string(),
        ]);
    }
    table.render()
}

fn row_cells(c: &Candidate) -> Vec<String> {
    let bc = c.backend_candidate();
    vec![
        c.name(),
        c.arch_key.clone(),
        c.config.spec.width.to_string(),
        c.config.spec.integer.to_string(),
        c.config.reuse.kernel.to_string(),
        c.config.reuse.recurrent.to_string(),
        c.config.strategy.label().to_string(),
        c.config.mode.label().to_string(),
        format!("{:.0}", c.config.clock_mhz),
        format!("{:.3}", c.latency_ns()),
        format!("{:.3}", c.ii_ns()),
        c.resources.dsp.to_string(),
        c.resources.lut.to_string(),
        c.resources.ff.to_string(),
        c.resources.bram_18k.to_string(),
        match c.auc {
            Some(auc) => format!("{auc:.6}"),
            None => String::new(),
        },
        bc.backend.to_string(),
        bc.tier.name().to_string(),
    ]
}

/// Emit the front as CSV (one row per Pareto point, [`ROW_FIELDS`]
/// columns).
pub fn write_csv(
    path: impl AsRef<Path>,
    result: &ExploreResult,
) -> anyhow::Result<PathBuf> {
    let mut w = CsvWriter::new(path, &ROW_FIELDS);
    for c in result.front_rows() {
        w.row(&row_cells(c));
    }
    w.finish()
}

fn row_json(c: &Candidate) -> Value {
    let bc = c.backend_candidate();
    json::obj(vec![
        ("name", json::s(&c.name())),
        ("model", json::s(&c.arch_key)),
        ("width", json::num(c.config.spec.width as f64)),
        ("integer", json::num(c.config.spec.integer as f64)),
        ("reuse_kernel", json::num(c.config.reuse.kernel as f64)),
        ("reuse_recurrent", json::num(c.config.reuse.recurrent as f64)),
        ("strategy", json::s(c.config.strategy.label())),
        ("mode", json::s(c.config.mode.label())),
        ("clock_mhz", json::num(c.config.clock_mhz)),
        ("latency_ns", json::num(c.latency_ns())),
        ("ii_ns", json::num(c.ii_ns())),
        ("dsp", json::num(c.resources.dsp as f64)),
        ("lut", json::num(c.resources.lut as f64)),
        ("ff", json::num(c.resources.ff as f64)),
        ("bram_18k", json::num(c.resources.bram_18k as f64)),
        ("auc", c.auc.map(json::num).unwrap_or(Value::Null)),
        ("backend", json::s(bc.backend)),
        ("tier", json::s(bc.tier.name())),
    ])
}

/// Emit the run as machine-readable JSON (the CI bench artifact):
/// request echo (device, filters), grid/admitted/front counts, and one
/// row per front point.
pub fn write_bench_json(
    path: &Path,
    result: &ExploreResult,
) -> anyhow::Result<PathBuf> {
    let doc = json::obj(vec![
        ("bench", json::s("explore")),
        ("schema_version", json::num(1.0)),
        ("device", json::s(result.device.name)),
        ("grid", json::num(result.candidates.len() as f64)),
        ("admitted", json::num(result.admitted.len() as f64)),
        ("front", json::num(result.front.len() as f64)),
        (
            "budget_ns",
            result.filters.budget_ns.map(json::num).unwrap_or(Value::Null),
        ),
        (
            "min_auc",
            result.filters.min_auc.map(json::num).unwrap_or(Value::Null),
        ),
        (
            "rows",
            json::arr(result.front_rows().map(row_json).collect()),
        ),
    ]);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut text = doc.to_json();
    text.push('\n');
    std::fs::write(path, text)?;
    Ok(path.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::hls::explore::{pareto, Filters};
    use crate::hls::{
        latency, resource, Device, HlsConfig, ReuseFactor, Strategy,
    };
    use crate::model::{zoo, Cell};

    fn candidates() -> Vec<Candidate> {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        [(ReuseFactor::new(1, 1), 16), (ReuseFactor::new(6, 5), 8)]
            .into_iter()
            .map(|(reuse, width)| {
                let mut cfg =
                    HlsConfig::paper_default(FixedSpec::new(width, 6), reuse);
                cfg.strategy = Strategy::Resource;
                Candidate {
                    arch_key: arch.key(),
                    config: cfg,
                    timing: latency::schedule(&arch, &cfg).unwrap(),
                    resources: resource::estimate(&arch, &cfg),
                    fits_device: true,
                    auc: (width == 16).then_some(0.9876),
                }
            })
            .collect()
    }

    fn result() -> ExploreResult {
        pareto(Device::KU115, candidates(), Filters::default())
    }

    #[test]
    fn table_renders_every_front_row() {
        let r = result();
        let text = render(&r);
        assert!(text.contains("Design-space Pareto front on KU115"));
        assert!(text.contains("top_gru"));
        assert!(text.contains("0.9876"));
        assert_eq!(
            text.lines().count(),
            // title + header + separator + one line per front row
            3 + r.front.len()
        );
    }

    #[test]
    fn csv_has_row_fields_header() {
        let dir = std::env::temp_dir().join(format!(
            "rnnhls-explore-csv-{}",
            std::process::id()
        ));
        let path = dir.join("explore.csv");
        write_csv(&path, &result()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(text.starts_with(&ROW_FIELDS.join(",")));
        // Missing AUC serializes as an empty cell, not a sentinel.
        assert!(text.contains(",resource,static,200,"));
    }

    #[test]
    fn bench_json_has_the_grepped_schema_and_is_byte_stable() {
        let dir = std::env::temp_dir().join(format!(
            "rnnhls-explore-json-{}",
            std::process::id()
        ));
        let a = dir.join("a.json");
        let b = dir.join("b.json");
        write_bench_json(&a, &result()).unwrap();
        write_bench_json(&b, &result()).unwrap();
        let ta = std::fs::read_to_string(&a).unwrap();
        let tb = std::fs::read_to_string(&b).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(ta, tb, "same grid must serialize byte-identically");
        for marker in [
            "\"bench\":\"explore\"",
            "\"schema_version\":1",
            "\"device\":\"KU115\"",
            "\"budget_ns\":null",
            "\"min_auc\":null",
            "\"auc\":",
            "\"tier\":\"trigger\"",
            "\"backend\":\"fixed\"",
            "\"name\":\"top_gru_w8i6_r6x5_resource_static_c200\"",
        ] {
            assert!(ta.contains(marker), "missing {marker} in {ta}");
        }
        let doc = crate::util::json::parse(&ta).unwrap();
        let rows = doc.req("rows").unwrap().as_array().unwrap();
        assert!(!rows.is_empty());
    }
}
