//! Report emitters: regenerate every table and figure of the paper's
//! evaluation as ASCII tables (stdout) + CSV files (for plotting).
//!
//! | paper artifact | function |
//! |---|---|
//! | Table 1 (hyperparameters/params)    | [`tables::table1`] |
//! | Fig. 2 (PTQ AUC ratio scan)         | [`fig2::run`] |
//! | Figs. 3–5 (DSP/FF/LUT vs width)     | [`resources::figs345`] |
//! | Tables 2–4 (latency bands)          | [`tables::latency_tables`] |
//! | Fig. 6 + Table 5 (static/non-static)| [`resources::fig6`], [`tables::table5`] |
//! | §5.2 throughput (FPGA vs GPU-analog)| [`throughput::run`] |
//!
//! Beyond the paper's own artifacts, [`accuracy`] sweeps an imported
//! checkpoint's float-vs-fixed AUC, and [`explore`] renders the HLS
//! design-space explorer's Pareto front (table/CSV/`BENCH_explore.json`).

pub mod accuracy;
pub mod csv;
pub mod explore;
pub mod fig2;
pub mod resources;
pub mod table;
pub mod tables;
pub mod throughput;

pub use table::AsciiTable;
