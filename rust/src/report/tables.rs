//! Tables 1–5 reproduction (paper-vs-model side by side).

use std::path::Path;

use crate::fixed::FixedSpec;
use crate::hls::latency::{self, Strategy};
use crate::hls::{paper, HlsConfig, ReuseFactor, RnnMode};
use crate::model::{zoo, Cell};

use super::csv::CsvWriter;
use super::table::AsciiTable;

/// Table 1: hyperparameters and trainable-parameter counts.
pub fn table1(out_dir: Option<&Path>) -> anyhow::Result<AsciiTable> {
    let mut table = AsciiTable::new(
        "Table 1: network hyperparameters and trainable parameters",
        &[
            "benchmark", "seq", "input", "hidden", "dense", "out",
            "non-RNN", "LSTM", "GRU",
        ],
    );
    let mut csv = out_dir.map(|dir| {
        CsvWriter::new(
            dir.join("table1_params.csv"),
            &["benchmark", "non_rnn", "lstm", "gru"],
        )
    });
    for name in zoo::BENCHMARKS {
        let lstm = zoo::arch(name, Cell::Lstm)?;
        let gru = zoo::arch(name, Cell::Gru)?;
        let dense = lstm
            .dense_sizes
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join("/");
        table.row(vec![
            name.to_string(),
            lstm.seq_len.to_string(),
            lstm.input_size.to_string(),
            lstm.hidden_size.to_string(),
            dense,
            lstm.output_size.to_string(),
            lstm.non_rnn_param_count().to_string(),
            lstm.rnn_param_count().to_string(),
            gru.rnn_param_count().to_string(),
        ]);
        if let Some(csv) = csv.as_mut() {
            csv.row(&[
                name.to_string(),
                lstm.non_rnn_param_count().to_string(),
                lstm.rnn_param_count().to_string(),
                gru.rnn_param_count().to_string(),
            ]);
        }
    }
    println!("{}", table.render());
    if let Some(csv) = csv {
        println!("wrote {}", csv.finish()?.display());
    }
    Ok(table)
}

/// One row of a latency-table comparison.
#[derive(Debug, Clone)]
pub struct LatencyRow {
    pub key: String,
    pub reuse: ReuseFactor,
    pub model_min_us: f64,
    pub model_max_us: f64,
    pub paper_min_us: f64,
    pub paper_max_us: f64,
}

impl LatencyRow {
    /// Relative error of the model's minimum latency vs the paper's.
    pub fn min_rel_err(&self) -> f64 {
        (self.model_min_us - self.paper_min_us).abs() / self.paper_min_us
    }
}

/// Tables 2–4: min/max latency per reuse factor, model vs paper.
pub fn latency_tables(
    benchmark: &str,
    out_dir: Option<&Path>,
) -> anyhow::Result<Vec<LatencyRow>> {
    let table_no = match benchmark {
        "top" => 2,
        "flavor" => 3,
        "quickdraw" => 4,
        other => anyhow::bail!("no latency table for {other:?}"),
    };
    let mut rows = Vec::new();
    let mut table = AsciiTable::new(
        format!("Table {table_no}: {benchmark} latencies (model vs paper, µs)"),
        &["model", "R", "model min-max", "paper min-max", "err(min)"],
    );
    for cell in [Cell::Gru, Cell::Lstm] {
        let arch = zoo::arch(benchmark, cell)?;
        // Latency-strategy column (top tagging only, Table 2).
        if benchmark == "top" {
            let (lo, hi) = latency::latency_band(
                &arch,
                ReuseFactor::fully_parallel(),
                Strategy::Latency,
            )?;
            table.row(vec![
                arch.key(),
                "latency".into(),
                format!("{lo:.1}-{hi:.1}"),
                format!(
                    "{:.1}-{:.1}",
                    paper::TOP_LATENCY_STRATEGY_US,
                    paper::TOP_LATENCY_STRATEGY_US
                ),
                format!(
                    "{:.0}%",
                    100.0 * (lo - paper::TOP_LATENCY_STRATEGY_US).abs()
                        / paper::TOP_LATENCY_STRATEGY_US
                ),
            ]);
        }
        for paper_row in paper::latency_table(benchmark, cell) {
            let (lo, hi) = latency::latency_band(
                &arch,
                paper_row.reuse,
                Strategy::Resource,
            )?;
            let row = LatencyRow {
                key: arch.key(),
                reuse: paper_row.reuse,
                model_min_us: lo,
                model_max_us: hi,
                paper_min_us: paper_row.min_us,
                paper_max_us: paper_row.max_us,
            };
            table.row(vec![
                row.key.clone(),
                row.reuse.label(),
                format!("{:.1}-{:.1}", row.model_min_us, row.model_max_us),
                format!("{:.1}-{:.1}", row.paper_min_us, row.paper_max_us),
                format!("{:.0}%", 100.0 * row.min_rel_err()),
            ]);
            rows.push(row);
        }
    }
    println!("{}", table.render());
    if let Some(dir) = out_dir {
        let mut csv = CsvWriter::new(
            dir.join(format!("table{table_no}_latency_{benchmark}.csv")),
            &[
                "model", "reuse_kernel", "reuse_recurrent",
                "model_min_us", "model_max_us", "paper_min_us", "paper_max_us",
            ],
        );
        for r in &rows {
            csv.row(&[
                r.key.clone(),
                r.reuse.kernel.to_string(),
                r.reuse.recurrent.to_string(),
                format!("{:.2}", r.model_min_us),
                format!("{:.2}", r.model_max_us),
                format!("{:.2}", r.paper_min_us),
                format!("{:.2}", r.paper_max_us),
            ]);
        }
        println!("wrote {}", csv.finish()?.display());
    }
    Ok(rows)
}

/// Table 5: static vs non-static latency and II for the top-tagging
/// models (latency strategy, the paper's configuration).
pub fn table5(out_dir: Option<&Path>) -> anyhow::Result<AsciiTable> {
    let mut table = AsciiTable::new(
        "Table 5: top tagging static vs non-static (model vs paper)",
        &[
            "model", "static lat µs (paper)", "non-static lat µs (paper)",
            "static II (paper)", "non-static II (paper)",
        ],
    );
    let mut csv = out_dir.map(|dir| {
        CsvWriter::new(
            dir.join("table5_modes.csv"),
            &["model", "mode", "latency_us", "ii_cycles"],
        )
    });
    for paper_row in paper::TABLE5 {
        let arch = zoo::arch("top", paper_row.cell)?;
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(10, 6),
            ReuseFactor::fully_parallel(),
        );
        cfg.strategy = Strategy::Latency;
        let stat = latency::schedule(&arch, &cfg)?;
        cfg.mode = RnnMode::NonStatic;
        let non = latency::schedule(&arch, &cfg)?;
        table.row(vec![
            arch.key(),
            format!("{:.1} ({:.1})", stat.latency_us, paper_row.static_latency_us),
            format!(
                "{:.1} ({:.1})",
                non.latency_us, paper_row.nonstatic_latency_us
            ),
            format!("{} ({})", stat.ii_cycles, paper_row.static_ii),
            format!("{} ({})", non.ii_cycles, paper_row.nonstatic_ii),
        ]);
        if let Some(csv) = csv.as_mut() {
            csv.row(&[
                arch.key(),
                "static".into(),
                format!("{:.2}", stat.latency_us),
                stat.ii_cycles.to_string(),
            ]);
            csv.row(&[
                arch.key(),
                "non-static".into(),
                format!("{:.2}", non.latency_us),
                non.ii_cycles.to_string(),
            ]);
        }
    }
    println!("{}", table.render());
    if let Some(csv) = csv {
        println!("wrote {}", csv.finish()?.display());
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_three_benchmarks() {
        let t = table1(None).unwrap();
        assert_eq!(t.n_rows(), 3);
    }

    #[test]
    fn latency_tables_match_paper_within_tolerance() {
        for (benchmark, tol) in [("top", 0.15), ("flavor", 0.20), ("quickdraw", 0.10)]
        {
            let rows = latency_tables(benchmark, None).unwrap();
            assert_eq!(rows.len(), 8); // 4 reuse × 2 cells
            for row in rows {
                assert!(
                    row.min_rel_err() < tol,
                    "{benchmark} {} R={}: {:.2} vs paper {:.2}",
                    row.key,
                    row.reuse.label(),
                    row.model_min_us,
                    row.paper_min_us
                );
            }
        }
    }

    #[test]
    fn table5_builds() {
        let t = table5(None).unwrap();
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn unknown_benchmark_rejected() {
        assert!(latency_tables("higgs", None).is_err());
    }
}
