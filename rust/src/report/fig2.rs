//! Fig. 2 reproduction: post-training-quantization scan.
//!
//! For each benchmark model, evaluate the bit-accurate [`FixedEngine`]
//! over the frozen test set at every (integer, fractional) bit
//! combination of the paper's grid and report the ratio of the quantized
//! AUC to the float AUC — the exact quantity plotted in Fig. 2.

use std::path::Path;

use crate::config::Fig2Config;
use crate::data::{metrics, Dataset};
use crate::fixed::{FixedSpec, QuantConfig};
use crate::model::Weights;
use crate::nn::{Engine, FixedEngine, FloatEngine};
use crate::runtime::Manifest;
use crate::util::threads::parallel_map;

use super::csv::CsvWriter;
use super::table::AsciiTable;

/// One scan point.
#[derive(Debug, Clone)]
pub struct Fig2Point {
    pub key: String,
    pub integer_bits: u32,
    pub fractional_bits: u32,
    pub auc_fixed: f64,
    pub auc_float: f64,
}

impl Fig2Point {
    pub fn ratio(&self) -> f64 {
        if self.auc_float <= 0.0 {
            return 0.0;
        }
        self.auc_fixed / self.auc_float
    }
}

/// Evaluate an engine over a dataset, in parallel over samples.
pub fn eval_probs(
    engine: &dyn Engine,
    ds: &Dataset,
    workers: usize,
) -> Vec<Vec<f32>> {
    parallel_map(ds.n, workers, |i| engine.forward(ds.sample(i)))
}

/// AUC of an engine over a dataset.
pub fn eval_auc(engine: &dyn Engine, ds: &Dataset, workers: usize) -> f64 {
    let probs = eval_probs(engine, ds, workers);
    metrics::mean_auc(&probs, ds.labels(), ds.n_classes)
}

/// Run the scan for every requested model.  Prints a summary table and
/// writes `fig2_<key>.csv` per model when `out_dir` is given.
pub fn run(
    artifacts: &Path,
    cfg: &Fig2Config,
    out_dir: Option<&Path>,
) -> anyhow::Result<Vec<Fig2Point>> {
    let manifest = Manifest::load(artifacts)?;
    let mut all_points = Vec::new();

    for key in &cfg.keys {
        let entry = manifest.model(key)?;
        let weights = Weights::load(manifest.path(&entry.weights))?;
        let ds = Dataset::load(manifest.path(&entry.dataset))?
            .truncated(cfg.samples);

        let float_engine = FloatEngine::new(&weights)?;
        let auc_float = eval_auc(&float_engine, &ds, cfg.workers);

        // Grid of (integer, fractional) pairs, engine-width capped.
        let grid: Vec<(u32, u32)> = cfg
            .integer_bits
            .iter()
            .flat_map(|&i| {
                cfg.fractional_bits.iter().filter_map(move |&f| {
                    if i + f <= crate::nn::fixed_engine::MAX_WIDTH {
                        Some((i, f))
                    } else {
                        None
                    }
                })
            })
            .collect();

        // One engine per grid point; points are independent, so
        // parallelize across points and keep per-point eval serial.
        let aucs = parallel_map(grid.len(), cfg.workers, |g| {
            let (int_bits, frac_bits) = grid[g];
            let spec = FixedSpec::new(int_bits + frac_bits, int_bits);
            let engine = FixedEngine::new(&weights, QuantConfig::ptq(spec))
                .expect("grid width within engine max");
            eval_auc(&engine, &ds, 1)
        });

        let mut table = AsciiTable::new(
            format!(
                "Fig. 2 ({key}): AUC(fixed)/AUC(float), float AUC {auc_float:.4}, {} samples",
                ds.n
            ),
            &["int\\frac", "2", "4", "6", "8", "10", "12", "14"],
        );
        for &int_bits in &cfg.integer_bits {
            let mut cells = vec![format!("{int_bits}")];
            for frac in [2u32, 4, 6, 8, 10, 12, 14] {
                let cell = grid
                    .iter()
                    .position(|&(i, f)| i == int_bits && f == frac)
                    .map(|idx| {
                        format!("{:.3}", aucs[idx] / auc_float.max(1e-12))
                    })
                    .unwrap_or_else(|| "-".into());
                cells.push(cell);
            }
            table.row(cells);
        }
        println!("{}", table.render());

        let mut points = Vec::new();
        for (g, &(int_bits, frac_bits)) in grid.iter().enumerate() {
            points.push(Fig2Point {
                key: key.clone(),
                integer_bits: int_bits,
                fractional_bits: frac_bits,
                auc_fixed: aucs[g],
                auc_float,
            });
        }
        if let Some(dir) = out_dir {
            let mut csv = CsvWriter::new(
                dir.join(format!("fig2_{key}.csv")),
                &["integer_bits", "fractional_bits", "auc_fixed", "auc_float", "ratio"],
            );
            for p in &points {
                csv.row(&[
                    p.integer_bits.to_string(),
                    p.fractional_bits.to_string(),
                    format!("{:.6}", p.auc_fixed),
                    format!("{:.6}", p.auc_float),
                    format!("{:.6}", p.ratio()),
                ]);
            }
            let path = csv.finish()?;
            println!("wrote {}", path.display());
        }
        all_points.extend(points);
    }
    Ok(all_points)
}

/// Paper-shape checks on a completed scan (used by the integration test
/// and EXPERIMENTS.md): at ≥10 fractional bits and the chosen integer
/// width, the ratio must be ≥ the low-precision ratios and near 1.
pub fn shape_check(points: &[Fig2Point], key: &str) -> anyhow::Result<()> {
    let benchmark = key.split('_').next().unwrap_or(key);
    let int_bits = crate::hls::paper::chosen_integer_bits(benchmark);
    let at = |frac: u32| -> Option<f64> {
        points
            .iter()
            .find(|p| {
                p.key == key
                    && p.integer_bits == int_bits
                    && p.fractional_bits == frac
            })
            .map(|p| p.ratio())
    };
    let lo = at(2).ok_or_else(|| anyhow::anyhow!("{key}: no frac=2 point"))?;
    let hi = at(12).or_else(|| at(10)).ok_or_else(|| {
        anyhow::anyhow!("{key}: no frac=10/12 point")
    })?;
    anyhow::ensure!(
        hi >= lo - 1e-9,
        "{key}: ratio at high precision ({hi:.4}) < at 2 frac bits ({lo:.4})"
    );
    anyhow::ensure!(
        hi > 0.95,
        "{key}: ratio at >=10 fractional bits only {hi:.4} (paper: ~1)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(key: &str, i: u32, f_bits: u32, fixed: f64, float: f64) -> Fig2Point {
        Fig2Point {
            key: key.into(),
            integer_bits: i,
            fractional_bits: f_bits,
            auc_fixed: fixed,
            auc_float: float,
        }
    }

    #[test]
    fn ratio_handles_degenerate_float() {
        assert_eq!(pt("k", 6, 2, 0.5, 0.0).ratio(), 0.0);
        assert!((pt("k", 6, 10, 0.99, 0.99).ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn shape_check_accepts_saturating_curve() {
        let points = vec![
            pt("top_gru", 6, 2, 0.70, 0.99),
            pt("top_gru", 6, 10, 0.985, 0.99),
            pt("top_gru", 6, 12, 0.99, 0.99),
        ];
        shape_check(&points, "top_gru").unwrap();
    }

    #[test]
    fn shape_check_rejects_broken_curve() {
        let points = vec![
            pt("top_gru", 6, 2, 0.99, 0.99),
            pt("top_gru", 6, 12, 0.60, 0.99),
        ];
        assert!(shape_check(&points, "top_gru").is_err());
    }
}
