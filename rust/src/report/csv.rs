//! CSV emission for plots (one file per reproduced figure/table).

use std::io::Write;
use std::path::{Path, PathBuf};

/// A CSV writer that creates its parent directory.
pub struct CsvWriter {
    path: PathBuf,
    lines: Vec<String>,
}

impl CsvWriter {
    pub fn new(path: impl AsRef<Path>, header: &[&str]) -> Self {
        Self {
            path: path.as_ref().to_path_buf(),
            lines: vec![header.join(",")],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.lines.push(
            cells
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect::<Vec<_>>()
                .join(","),
        );
    }

    /// Write the file, returning its path.
    pub fn finish(self) -> anyhow::Result<PathBuf> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(&self.path)?;
        for line in &self.lines {
            writeln!(file, "{line}")?;
        }
        Ok(self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join(format!(
            "rnnhls-csv-test-{}",
            std::process::id()
        ));
        let path = dir.join("sub/out.csv");
        let mut w = CsvWriter::new(&path, &["a", "b"]);
        w.row(&["1".into(), "plain".into()]);
        w.row(&["2".into(), "has,comma \"q\"".into()]);
        let written = w.finish().unwrap();
        let text = std::fs::read_to_string(written).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("2,\"has,comma \"\"q\"\"\""));
        std::fs::remove_dir_all(dir).ok();
    }
}
