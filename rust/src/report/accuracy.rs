//! Float-vs-fixed accuracy sweep over a real checkpoint — the paper's
//! §4 accuracy study (Fig. 2 reports the same scan as ratios over the
//! synthetic-artifact grid; this report runs *imported* weights on the
//! bundled dataset slice and pins absolute AUC + delta per precision).
//!
//! The output contract is `BENCH_accuracy.json` (see
//! [`write_bench_json`]); `ci.sh --bench-smoke` greps its schema and the
//! `accuracy_golden` tier-1 test pins the AUC values.

use std::path::{Path, PathBuf};

use crate::data::{metrics, Dataset};
use crate::fixed::{FixedSpec, QuantConfig};
use crate::model::Weights;
use crate::nn::fixed_engine::MAX_WIDTH;
use crate::nn::{FixedEngine, FloatEngine};
use crate::util::json;
use crate::util::threads::parallel_map;

use super::fig2::{eval_auc, eval_probs};
use super::table::AsciiTable;

/// One fixed-point precision's result.
#[derive(Debug, Clone)]
pub struct AccuracyPoint {
    pub spec: FixedSpec,
    pub auc_fixed: f64,
}

/// The sweep result for one model.
#[derive(Debug, Clone)]
pub struct AccuracyReport {
    /// Model-zoo key, e.g. `top_gru`.
    pub key: String,
    /// Events evaluated.
    pub samples: usize,
    /// Float (f32) baseline AUC.
    pub auc_float: f64,
    pub points: Vec<AccuracyPoint>,
}

impl AccuracyReport {
    /// `auc_fixed - auc_float` for one point (negative = quantization
    /// loss).
    pub fn delta(&self, p: &AccuracyPoint) -> f64 {
        p.auc_fixed - self.auc_float
    }

    /// The point with the given spec, if scanned.
    pub fn point(&self, width: u32, integer: u32) -> Option<&AccuracyPoint> {
        self.points
            .iter()
            .find(|p| p.spec.width == width && p.spec.integer == integer)
    }
}

/// The default precision ladder: two clearly-degraded low widths, the
/// hls4ml default `<16,6>`, and a near-float wide type.
pub fn default_specs() -> Vec<FixedSpec> {
    [(8, 4), (12, 6), (16, 6), (20, 8)]
        .into_iter()
        .map(|(w, i)| FixedSpec::new(w, i))
        .collect()
}

/// Parse a `"W:I,W:I,..."` spec list (e.g. `"16:6,20:8"`), validating
/// ranges up front — [`FixedSpec::new`] treats bad combinations as
/// programming errors and panics, which a CLI flag must never reach.
pub fn parse_specs(csv: &str) -> anyhow::Result<Vec<FixedSpec>> {
    let mut specs = Vec::new();
    for part in csv.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (w, i) = part.split_once(':').ok_or_else(|| {
            anyhow::anyhow!("bad spec {part:?} (want WIDTH:INTEGER, e.g. 16:6)")
        })?;
        let width: u32 = w
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad width in spec {part:?}"))?;
        let integer: u32 = i
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad integer bits in spec {part:?}"))?;
        anyhow::ensure!(
            (1..=MAX_WIDTH).contains(&width),
            "spec {part:?}: width {width} out of range 1..={MAX_WIDTH}"
        );
        anyhow::ensure!(
            (1..=width).contains(&integer),
            "spec {part:?}: integer bits {integer} out of range 1..={width}"
        );
        specs.push(FixedSpec::new(width, integer));
    }
    anyhow::ensure!(!specs.is_empty(), "no fixed-point specs given");
    Ok(specs)
}

/// The float (f32) reference evaluation for one (checkpoint, dataset)
/// pair, computed once and reused across any number of fixed-point
/// evaluations — [`run`] sweeps a spec ladder through it, and the HLS
/// design-space explorer joins per-precision AUC from it without
/// re-running the baseline per candidate.
pub struct FloatBaseline<'a> {
    weights: &'a Weights,
    ds: &'a Dataset,
    auc_float: f64,
}

impl<'a> FloatBaseline<'a> {
    /// Validate dataset-vs-architecture shape and evaluate the float
    /// reference.
    pub fn new(
        weights: &'a Weights,
        ds: &'a Dataset,
        workers: usize,
    ) -> anyhow::Result<Self> {
        let arch = &weights.arch;
        anyhow::ensure!(
            ds.seq_len == arch.seq_len && ds.n_feat == arch.input_size,
            "dataset shape ({} steps x {} features) does not feed {} \
             ({} x {})",
            ds.seq_len,
            ds.n_feat,
            arch.key(),
            arch.seq_len,
            arch.input_size
        );
        anyhow::ensure!(
            ds.n_classes == arch.n_classes(),
            "dataset has {} classes but {} outputs {}",
            ds.n_classes,
            arch.key(),
            arch.n_classes()
        );
        let float_engine = FloatEngine::new(weights)?;
        let probs = eval_probs(&float_engine, ds, workers);
        // The float baseline must be clean; the fixed paths may saturate
        // into NaN at very low widths, which binary_auc excludes
        // per-sample.
        metrics::require_finite(&probs)
            .map_err(|e| anyhow::anyhow!("float baseline: {e}"))?;
        let auc_float = metrics::mean_auc(&probs, ds.labels(), ds.n_classes);
        Ok(Self {
            weights,
            ds,
            auc_float,
        })
    }

    /// Float reference AUC.
    pub fn auc_float(&self) -> f64 {
        self.auc_float
    }

    /// Events in the evaluation slice.
    pub fn samples(&self) -> usize {
        self.ds.n
    }

    /// Model-zoo key of the checkpoint, e.g. `top_gru`.
    pub fn key(&self) -> String {
        self.weights.arch.key()
    }

    /// Measured AUC of one fixed-point precision (PTQ config:
    /// truncation + saturation).
    pub fn eval_spec(
        &self,
        spec: FixedSpec,
        workers: usize,
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(
            spec.width <= MAX_WIDTH,
            "spec {} exceeds engine max width {MAX_WIDTH}",
            spec.label()
        );
        let engine = FixedEngine::new(self.weights, QuantConfig::ptq(spec))?;
        Ok(eval_auc(&engine, self.ds, workers))
    }

    /// Sweep a precision ladder against this baseline, parallel over
    /// specs.
    pub fn sweep(
        &self,
        specs: &[FixedSpec],
        workers: usize,
    ) -> anyhow::Result<AccuracyReport> {
        for spec in specs {
            anyhow::ensure!(
                spec.width <= MAX_WIDTH,
                "spec {} exceeds engine max width {MAX_WIDTH}",
                spec.label()
            );
        }
        let aucs = parallel_map(specs.len(), workers, |s| {
            self.eval_spec(specs[s], 1)
                .expect("spec width validated against engine max")
        });
        Ok(AccuracyReport {
            key: self.key(),
            samples: self.samples(),
            auc_float: self.auc_float,
            points: specs
                .iter()
                .zip(aucs)
                .map(|(&spec, auc_fixed)| AccuracyPoint { spec, auc_fixed })
                .collect(),
        })
    }
}

/// Run the sweep: float baseline plus one [`FixedEngine`] per spec
/// (PTQ config: truncation + saturation), parallel over specs.
pub fn run(
    weights: &Weights,
    ds: &Dataset,
    specs: &[FixedSpec],
    workers: usize,
) -> anyhow::Result<AccuracyReport> {
    FloatBaseline::new(weights, ds, workers)?.sweep(specs, workers)
}

/// Render one report as an ASCII table.
pub fn render(report: &AccuracyReport) -> String {
    let mut table = AsciiTable::new(
        format!(
            "Accuracy ({}): float AUC {:.4}, {} samples",
            report.key, report.auc_float, report.samples
        ),
        &["type", "auc_fixed", "delta", "ratio"],
    );
    for p in &report.points {
        let ratio = if report.auc_float > 0.0 {
            p.auc_fixed / report.auc_float
        } else {
            0.0
        };
        table.row(vec![
            format!("ap_fixed{}", p.spec.label()),
            format!("{:.4}", p.auc_fixed),
            format!("{:+.4}", report.delta(p)),
            format!("{ratio:.3}"),
        ]);
    }
    table.render()
}

/// Emit the sweep as machine-readable JSON (the CI bench artifact).
pub fn write_bench_json(
    path: &Path,
    reports: &[AccuracyReport],
) -> anyhow::Result<PathBuf> {
    let doc = json::obj(vec![
        ("bench", json::s("accuracy")),
        ("schema_version", json::num(1.0)),
        (
            "models",
            json::arr(
                reports
                    .iter()
                    .map(|r| {
                        json::obj(vec![
                            ("key", json::s(&r.key)),
                            ("samples", json::num(r.samples as f64)),
                            ("auc_float", json::num(r.auc_float)),
                            (
                                "rows",
                                json::arr(
                                    r.points
                                        .iter()
                                        .map(|p| {
                                            json::obj(vec![
                                                (
                                                    "width",
                                                    json::num(
                                                        p.spec.width as f64,
                                                    ),
                                                ),
                                                (
                                                    "integer",
                                                    json::num(
                                                        p.spec.integer as f64,
                                                    ),
                                                ),
                                                (
                                                    "auc_fixed",
                                                    json::num(p.auc_fixed),
                                                ),
                                                (
                                                    "delta",
                                                    json::num(r.delta(p)),
                                                ),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut text = doc.to_json();
    text.push('\n');
    std::fs::write(path, text)?;
    Ok(path.to_path_buf())
}

/// Paper-shape checks on a completed sweep: the float baseline must
/// actually separate the classes, the widest precision must sit near it
/// (Fig. 2: AUC saturates with width), and widening must not lose
/// accuracy.
pub fn shape_check(report: &AccuracyReport) -> anyhow::Result<()> {
    anyhow::ensure!(
        report.auc_float > 0.55,
        "{}: float AUC {:.4} is not better than chance — not a trained \
         checkpoint?",
        report.key,
        report.auc_float
    );
    let widest = report
        .points
        .iter()
        .max_by_key(|p| p.spec.width)
        .ok_or_else(|| anyhow::anyhow!("{}: empty sweep", report.key))?;
    anyhow::ensure!(
        report.delta(widest).abs() <= 0.05,
        "{}: widest spec {} is {:.4} from float ({:.4} vs {:.4})",
        report.key,
        widest.spec.label(),
        report.delta(widest),
        widest.auc_fixed,
        report.auc_float
    );
    let narrowest = report
        .points
        .iter()
        .min_by_key(|p| p.spec.width)
        .ok_or_else(|| anyhow::anyhow!("{}: empty sweep", report.key))?;
    anyhow::ensure!(
        widest.auc_fixed >= narrowest.auc_fixed - 0.02,
        "{}: widening {} -> {} lost AUC ({:.4} -> {:.4})",
        report.key,
        narrowest.spec.label(),
        widest.spec.label(),
        narrowest.auc_fixed,
        widest.auc_fixed
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_ladder_is_engine_legal() {
        let specs = default_specs();
        assert_eq!(specs.len(), 4);
        assert!(specs.iter().any(|s| s.label() == "<16,6>"));
        for s in &specs {
            assert!(s.width <= MAX_WIDTH);
        }
    }

    #[test]
    fn parse_specs_roundtrips() {
        let specs = parse_specs("8:4, 16:6,20:8").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[1], FixedSpec::new(16, 6));
    }

    #[test]
    fn parse_specs_rejects_bad_input_without_panicking() {
        // Each of these would be a panic if fed straight to
        // FixedSpec::new.
        assert!(parse_specs("0:0").is_err());
        assert!(parse_specs("8:9").is_err());
        assert!(parse_specs("99:6").is_err());
        assert!(parse_specs("16").is_err());
        assert!(parse_specs("a:b").is_err());
        assert!(parse_specs("").is_err());
    }

    fn toy_report() -> AccuracyReport {
        AccuracyReport {
            key: "top_gru".into(),
            samples: 100,
            auc_float: 0.99,
            points: vec![
                AccuracyPoint {
                    spec: FixedSpec::new(8, 4),
                    auc_fixed: 0.6,
                },
                AccuracyPoint {
                    spec: FixedSpec::new(20, 8),
                    auc_fixed: 0.985,
                },
            ],
        }
    }

    #[test]
    fn shape_check_accepts_saturating_sweep() {
        shape_check(&toy_report()).unwrap();
    }

    #[test]
    fn shape_check_rejects_wide_precision_loss() {
        let mut r = toy_report();
        r.points[1].auc_fixed = 0.5;
        assert!(shape_check(&r).is_err());
    }

    #[test]
    fn shape_check_rejects_chance_baseline() {
        let mut r = toy_report();
        r.auc_float = 0.5;
        assert!(shape_check(&r).is_err());
    }

    #[test]
    fn bench_json_has_the_grepped_schema() {
        let path = std::env::temp_dir().join(format!(
            "bench_accuracy_unit_{}.json",
            std::process::id()
        ));
        write_bench_json(&path, &[toy_report()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        for marker in [
            "\"bench\":\"accuracy\"",
            "\"schema_version\":1",
            "\"key\":\"top_gru\"",
            "\"auc_float\":",
            "\"width\":8,\"integer\":4,",
            "\"width\":20,\"integer\":8,",
            "\"delta\":",
        ] {
            assert!(text.contains(marker), "missing {marker} in {text}");
        }
        let doc = crate::util::json::parse(&text).unwrap();
        let models = doc.req("models").unwrap().as_array().unwrap();
        assert_eq!(models.len(), 1);
    }
}
