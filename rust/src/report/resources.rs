//! Figs. 3–6 reproduction: resource utilization vs total bit width.

use std::path::Path;

use crate::config::SweepConfig;
use crate::fixed::FixedSpec;
use crate::hls::latency::Strategy;
use crate::hls::{paper, resource, Device, HlsConfig, ReuseFactor, RnnMode};
use crate::model::{zoo, Cell};

use super::csv::CsvWriter;
use super::table::AsciiTable;

/// One point of a resource figure.
#[derive(Debug, Clone)]
pub struct ResourcePoint {
    pub key: String,
    pub reuse: ReuseFactor,
    pub strategy: Strategy,
    pub mode: RnnMode,
    pub width: u32,
    pub dsp: u64,
    pub ff: u64,
    pub lut: u64,
    pub bram: u64,
}

fn scan(
    benchmark: &str,
    cell: Cell,
    widths: &[u32],
    reuse_set: &[ReuseFactor],
    strategy: Strategy,
    mode: RnnMode,
) -> anyhow::Result<Vec<ResourcePoint>> {
    let arch = zoo::arch(benchmark, cell)?;
    let mut out = Vec::new();
    for &reuse in reuse_set {
        for &width in widths {
            let integer = paper::chosen_integer_bits(benchmark).min(width - 1).max(1);
            let mut cfg =
                HlsConfig::paper_default(FixedSpec::new(width, integer), reuse);
            cfg.strategy = strategy;
            cfg.mode = mode;
            let est = resource::estimate(&arch, &cfg);
            out.push(ResourcePoint {
                key: arch.key(),
                reuse,
                strategy,
                mode,
                width,
                dsp: est.dsp,
                ff: est.ff,
                lut: est.lut,
                bram: est.bram_18k,
            });
        }
    }
    Ok(out)
}

/// Figs. 3, 4, 5: DSP/FF/LUT vs total width for every benchmark × cell ×
/// reuse column, plus the latency-strategy line for top tagging.
pub fn figs345(
    cfg: &SweepConfig,
    out_dir: Option<&Path>,
) -> anyhow::Result<Vec<ResourcePoint>> {
    let mut all = Vec::new();
    for cell in [Cell::Gru, Cell::Lstm] {
        let grid = paper::reuse_grid(&cfg.benchmark, cell);
        all.extend(scan(
            &cfg.benchmark,
            cell,
            &cfg.widths,
            &grid,
            Strategy::Resource,
            RnnMode::Static,
        )?);
        // Latency-strategy line exists only for the top-tagging models.
        if cfg.benchmark == "top" {
            all.extend(scan(
                &cfg.benchmark,
                cell,
                &cfg.widths,
                &[ReuseFactor::fully_parallel()],
                Strategy::Latency,
                RnnMode::Static,
            )?);
        }
    }
    let device = Device::for_benchmark(&cfg.benchmark);
    for (figure, pick) in [
        ("fig3_dsp", 0usize),
        ("fig4_ff", 1),
        ("fig5_lut", 2),
    ] {
        let mut table = AsciiTable::new(
            format!(
                "{figure} ({}), device {} (available: dsp {}, ff {}, lut {})",
                cfg.benchmark, device.name, device.dsps, device.ffs, device.luts
            ),
            &["model", "strategy", "R", "W=8", "W=14", "W=20", "W=26"],
        );
        for point_key in all
            .iter()
            .map(|p| (p.key.clone(), p.strategy, p.reuse))
            .collect::<std::collections::BTreeSet<_>>()
        {
            let (key, strategy, reuse) = &point_key;
            let mut cells = vec![
                key.clone(),
                strategy.label().to_string(),
                reuse.label(),
            ];
            for w in [8u32, 14, 20, 26] {
                let cell = all
                    .iter()
                    .find(|p| {
                        &p.key == key
                            && p.strategy == *strategy
                            && p.reuse == *reuse
                            && p.width == w
                    })
                    .map(|p| match pick {
                        0 => p.dsp.to_string(),
                        1 => p.ff.to_string(),
                        _ => p.lut.to_string(),
                    })
                    .unwrap_or_else(|| "-".into());
                cells.push(cell);
            }
            table.row(cells);
        }
        println!("{}", table.render());
        if let Some(dir) = out_dir {
            let mut csv = CsvWriter::new(
                dir.join(format!("{figure}_{}.csv", cfg.benchmark)),
                &["model", "strategy", "reuse", "width", "dsp", "ff", "lut", "bram"],
            );
            for p in &all {
                csv.row(&[
                    p.key.clone(),
                    p.strategy.label().into(),
                    p.reuse.label(),
                    p.width.to_string(),
                    p.dsp.to_string(),
                    p.ff.to_string(),
                    p.lut.to_string(),
                    p.bram.to_string(),
                ]);
            }
            println!("wrote {}", csv.finish()?.display());
        }
    }
    Ok(all)
}

/// Fig. 6: static vs non-static resources for the top-tagging models.
pub fn fig6(out_dir: Option<&Path>) -> anyhow::Result<Vec<ResourcePoint>> {
    let widths: Vec<u32> = (6..=20).step_by(2).collect();
    let mut all = Vec::new();
    for cell in [Cell::Gru, Cell::Lstm] {
        for mode in [RnnMode::Static, RnnMode::NonStatic] {
            all.extend(scan(
                "top",
                cell,
                &widths,
                &[ReuseFactor::fully_parallel()],
                Strategy::Latency,
                mode,
            )?);
        }
    }
    let device = Device::for_benchmark("top");
    let mut table = AsciiTable::new(
        format!(
            "Fig. 6: top tagging static vs non-static (device {}: dsp {}, ff {}, lut {})",
            device.name, device.dsps, device.ffs, device.luts
        ),
        &["model", "mode", "W", "DSP", "FF", "LUT", "fits"],
    );
    for p in &all {
        let fits = device.fits(&crate::hls::ResourceEstimate {
            dsp: p.dsp,
            lut: p.lut,
            ff: p.ff,
            bram_18k: p.bram,
        });
        table.row(vec![
            p.key.clone(),
            p.mode.label().into(),
            p.width.to_string(),
            p.dsp.to_string(),
            p.ff.to_string(),
            p.lut.to_string(),
            if fits { "yes".into() } else { "NO".into() },
        ]);
    }
    println!("{}", table.render());
    if let Some(dir) = out_dir {
        let mut csv = CsvWriter::new(
            dir.join("fig6_modes.csv"),
            &["model", "mode", "width", "dsp", "ff", "lut", "bram"],
        );
        for p in &all {
            csv.row(&[
                p.key.clone(),
                p.mode.label().into(),
                p.width.to_string(),
                p.dsp.to_string(),
                p.ff.to_string(),
                p.lut.to_string(),
                p.bram.to_string(),
            ]);
        }
        println!("wrote {}", csv.finish()?.display());
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figs345_cover_grid() {
        let cfg = SweepConfig {
            benchmark: "top".into(),
            widths: vec![8, 16],
        };
        let points = figs345(&cfg, None).unwrap();
        // 2 cells × (4 resource-reuse + 1 latency) × 2 widths
        assert_eq!(points.len(), 2 * 5 * 2);
        // monotone in width per series
        for p8 in points.iter().filter(|p| p.width == 8) {
            let p16 = points
                .iter()
                .find(|q| {
                    q.width == 16
                        && q.key == p8.key
                        && q.reuse == p8.reuse
                        && q.strategy == p8.strategy
                })
                .unwrap();
            assert!(p16.lut > p8.lut);
            assert!(p16.ff > p8.ff);
        }
    }

    #[test]
    fn fig6_nonstatic_dominates_static() {
        let points = fig6(None).unwrap();
        for cell in ["top_gru", "top_lstm"] {
            let stat: u64 = points
                .iter()
                .filter(|p| p.key == cell && p.mode == RnnMode::Static && p.width == 10)
                .map(|p| p.dsp)
                .sum();
            let non: u64 = points
                .iter()
                .filter(|p| {
                    p.key == cell && p.mode == RnnMode::NonStatic && p.width == 10
                })
                .map(|p| p.dsp)
                .sum();
            assert!(non > 10 * stat, "{cell}: non-static {non} vs static {stat}");
        }
    }
}
