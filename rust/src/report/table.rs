//! Aligned ASCII table rendering for report output.

/// A simple column-aligned table builder.
#[derive(Debug, Clone)]
pub struct AsciiTable {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl AsciiTable {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut out = String::from("| ");
            for (i, cell) in cells.iter().enumerate() {
                out.push_str(&format!("{:<width$} | ", cell, width = widths[i]));
            }
            out.trim_end().to_string()
        };
        let sep: String = {
            let mut out = String::from("|");
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('|');
            }
            out
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format helper: `12.34` etc. with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = AsciiTable::new("Demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| name      | value |"));
        assert!(s.contains("| long-name | 2.5   |"));
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_wrong_width() {
        let mut t = AsciiTable::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn float_format() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(f(2.0, 1), "2.0");
    }
}
