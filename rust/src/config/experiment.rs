//! Typed experiment configuration shared by the CLI and the benches.

/// Fig. 2 quantization-scan configuration.
#[derive(Debug, Clone)]
pub struct Fig2Config {
    /// Model keys to scan (default: all six).
    pub keys: Vec<String>,
    /// Evaluation samples per model (the frozen test set is truncated to
    /// this; smaller = faster, noisier).
    pub samples: usize,
    /// Integer-bit grid (paper: 6, 8, 10, 12).
    pub integer_bits: Vec<u32>,
    /// Fractional-bit grid (paper: 2..=14).
    pub fractional_bits: Vec<u32>,
    /// Worker threads for the scan.
    pub workers: usize,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Self {
            keys: vec![
                "top_lstm".into(),
                "top_gru".into(),
                "flavor_lstm".into(),
                "flavor_gru".into(),
                "quickdraw_lstm".into(),
                "quickdraw_gru".into(),
            ],
            samples: 1000,
            integer_bits: crate::hls::paper::FIG2_INTEGER_BITS.to_vec(),
            fractional_bits: crate::hls::paper::FIG2_FRACTIONAL_BITS
                .clone()
                .collect(),
            workers: crate::util::threads::default_workers(),
        }
    }
}

/// Resource/latency design-space sweep configuration (Figs. 3–6).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub benchmark: String,
    /// Total widths to scan (figures' x-axis).
    pub widths: Vec<u32>,
}

impl SweepConfig {
    pub fn paper(benchmark: &str) -> Self {
        Self {
            benchmark: benchmark.to_string(),
            widths: (8..=26).step_by(2).collect(),
        }
    }
}

// The `serve` subcommand's configuration is no longer a stringly struct
// here: the CLI parses its flags straight into the typed
// `coordinator::session::ServingSpec` (whose `Default` carries the serve
// defaults), and every serving invariant is validated in
// `ServingSpec::build`.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_defaults_match_paper_grid() {
        let cfg = Fig2Config::default();
        assert_eq!(cfg.integer_bits, vec![6, 8, 10, 12]);
        assert_eq!(cfg.fractional_bits.first(), Some(&2));
        assert_eq!(cfg.fractional_bits.last(), Some(&14));
        assert_eq!(cfg.keys.len(), 6);
    }

    /// The serve defaults moved to `ServingSpec::default` with the typed
    /// session API; they must stay the single-coordinator, single-class,
    /// single-threaded-engine session so existing invocations reproduce
    /// pre-session behavior exactly.
    #[test]
    fn serve_defaults_live_in_the_typed_serving_spec() {
        use crate::coordinator::{ServingSpec, ShardPolicy};
        let spec = ServingSpec::default();
        assert_eq!(spec.shards, 1);
        assert_eq!(spec.shard_policy, ShardPolicy::HashId);
        assert_eq!(spec.workers, 2);
        assert_eq!(spec.engine_parallelism, 1);
        assert_eq!(spec.batcher.max_batch, 10);
        assert!(spec.backends.is_empty());
        assert!(spec.tier_mix.is_none());
        assert_eq!(spec.tier_seed, 0);
        assert!(spec.batch_policy.is_none());
    }

    #[test]
    fn sweep_covers_widths() {
        let s = SweepConfig::paper("top");
        assert_eq!(s.widths.first(), Some(&8));
        assert_eq!(s.widths.last(), Some(&26));
    }
}
