//! Typed experiment configuration shared by the CLI and the benches.

use std::time::Duration;

/// Fig. 2 quantization-scan configuration.
#[derive(Debug, Clone)]
pub struct Fig2Config {
    /// Model keys to scan (default: all six).
    pub keys: Vec<String>,
    /// Evaluation samples per model (the frozen test set is truncated to
    /// this; smaller = faster, noisier).
    pub samples: usize,
    /// Integer-bit grid (paper: 6, 8, 10, 12).
    pub integer_bits: Vec<u32>,
    /// Fractional-bit grid (paper: 2..=14).
    pub fractional_bits: Vec<u32>,
    /// Worker threads for the scan.
    pub workers: usize,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Self {
            keys: vec![
                "top_lstm".into(),
                "top_gru".into(),
                "flavor_lstm".into(),
                "flavor_gru".into(),
                "quickdraw_lstm".into(),
                "quickdraw_gru".into(),
            ],
            samples: 1000,
            integer_bits: crate::hls::paper::FIG2_INTEGER_BITS.to_vec(),
            fractional_bits: crate::hls::paper::FIG2_FRACTIONAL_BITS
                .clone()
                .collect(),
            workers: crate::util::threads::default_workers(),
        }
    }
}

/// Resource/latency design-space sweep configuration (Figs. 3–6).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub benchmark: String,
    /// Total widths to scan (figures' x-axis).
    pub widths: Vec<u32>,
}

impl SweepConfig {
    pub fn paper(benchmark: &str) -> Self {
        Self {
            benchmark: benchmark.to_string(),
            widths: (8..=26).step_by(2).collect(),
        }
    }
}

/// `serve` subcommand configuration (mapped onto the coordinator).
#[derive(Debug, Clone)]
pub struct ServeCliConfig {
    pub model_key: String,
    /// Homogeneous engine for every shard: "pjrt" | "fixed" | "float".
    /// Ignored when `backends` is non-empty.
    pub engine: String,
    /// Heterogeneous session: comma-separated backend names, one per
    /// shard (`"fixed,float"`), resolved through the `nn::BackendSpec`
    /// registry.  Empty = homogeneous `engine` on every shard.
    pub backends: String,
    /// Traffic-class fractions, one per backend (`"0.9,0.1"`, summing to
    /// 1), stamped onto `Request::route_key`; requires `backends` and the
    /// `model-key` shard policy to steer tiers to their backends.  Empty
    /// = uniform across `backends`.
    pub tier_mix: String,
    /// Seed of the tier-stamping hash (a pure function of (seed, id)):
    /// same seed, same partition of the stream into tiers.
    pub tier_seed: u64,
    pub rate_hz: f64,
    pub n_events: usize,
    /// Coordinator shards: independent queue+batcher+worker pipelines the
    /// request stream is partitioned across.  1 = the classic single
    /// coordinator (bitwise-identical results to `Server`).
    pub shards: usize,
    /// Routing policy in front of the shards:
    /// "hash" | "round-robin" | "model-key".
    pub shard_policy: String,
    /// Engine-worker threads *per shard* (each owns one engine replica).
    pub workers: usize,
    /// Per-batch parallelism *inside* each rust engine (`forward_batch`
    /// worker pool; 1 = single-threaded engine).  Total thread budget is
    /// `shards × workers × engine_parallelism`.
    pub engine_parallelism: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Per-shard batching policy override, in the `--batch-policy`
    /// grammar: comma-separated `<name>:<max_batch>:<max_wait_us>`
    /// entries, one per shard (e.g. `trigger:1:0,offline:64:2000`).
    /// Empty = tier defaults for heterogeneous sessions (trigger
    /// backends pinned at batch-1/zero-wait, offline backends batching
    /// deep), the shared `max_batch`/`max_wait` otherwise.
    pub batch_policy: String,
    /// Per-shard queue capacity (drop beyond).
    pub queue_capacity: usize,
}

impl Default for ServeCliConfig {
    fn default() -> Self {
        Self {
            model_key: "top_gru".into(),
            engine: "pjrt".into(),
            backends: String::new(),
            tier_mix: String::new(),
            tier_seed: 0,
            rate_hz: 20_000.0,
            n_events: 50_000,
            shards: 1,
            shard_policy: "hash".into(),
            workers: 2,
            engine_parallelism: 1,
            max_batch: 10,
            max_wait: Duration::from_micros(200),
            batch_policy: String::new(),
            queue_capacity: 4096,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_defaults_match_paper_grid() {
        let cfg = Fig2Config::default();
        assert_eq!(cfg.integer_bits, vec![6, 8, 10, 12]);
        assert_eq!(cfg.fractional_bits.first(), Some(&2));
        assert_eq!(cfg.fractional_bits.last(), Some(&14));
        assert_eq!(cfg.keys.len(), 6);
    }

    #[test]
    fn serve_defaults_are_single_threaded_engines() {
        let cfg = ServeCliConfig::default();
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.engine_parallelism, 1);
        assert_eq!(cfg.max_batch, 10);
    }

    /// The default serve config must stay the single-coordinator setup so
    /// existing invocations reproduce pre-sharding behavior exactly.
    #[test]
    fn serve_defaults_to_one_shard_hash_policy() {
        let cfg = ServeCliConfig::default();
        assert_eq!(cfg.shards, 1);
        assert_eq!(cfg.shard_policy, "hash");
    }

    /// Likewise the default must stay the homogeneous single-class
    /// session: no backend list, no tier mix, no per-shard batch policy.
    #[test]
    fn serve_defaults_to_homogeneous_session() {
        let cfg = ServeCliConfig::default();
        assert!(cfg.backends.is_empty());
        assert!(cfg.tier_mix.is_empty());
        assert_eq!(cfg.tier_seed, 0);
        assert!(cfg.batch_policy.is_empty());
    }

    #[test]
    fn sweep_covers_widths() {
        let s = SweepConfig::paper("top");
        assert_eq!(s.widths.first(), Some(&8));
        assert_eq!(s.widths.last(), Some(&26));
    }
}
