//! Experiment configuration: typed descriptors for the CLI sweeps
//! (filled by `report::experiments`).

pub mod experiment;

pub use experiment::{Fig2Config, SweepConfig};
