//! Design roll-up: architecture + configuration → full synthesis report.

use crate::model::Arch;

use super::device::Device;
use super::latency::{self, DesignTiming, Strategy};
use super::resource::{self, ResourceEstimate};
use super::HlsConfig;

/// Typed rejection of an invalid configuration, raised at
/// [`HlsDesign::new`] — before any estimate is computed — so a bad knob
/// setting can never yield silently wrong numbers.
#[derive(Debug, Clone, PartialEq)]
pub enum DesignError {
    /// Under resource strategy, a reuse factor must divide the mult
    /// count it time-multiplexes (`DSP = mults / R` only binds whole
    /// DSP lanes when the division is exact — the rule behind the
    /// paper's bracketed `[40]`/`[256]` reuse quirks).
    ReuseNotDivisor {
        arch_key: String,
        /// Which matrix multiplication: `"kernel"` or `"recurrent"`.
        which: &'static str,
        reuse: usize,
        mults: usize,
    },
    /// The synthesis clock must be a positive, finite frequency.
    BadClock { clock_mhz: f64 },
}

impl std::fmt::Display for DesignError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DesignError::ReuseNotDivisor {
                arch_key,
                which,
                reuse,
                mults,
            } => {
                write!(
                    f,
                    "{arch_key}: {which} reuse factor {reuse} does not \
                     divide the {mults} {which} mults ({mults} % {reuse} = \
                     {}) — DSP = mults/R needs an exact divisor (cf. the \
                     paper's bracketed reuse values)",
                    mults % reuse
                )
            }
            DesignError::BadClock { clock_mhz } => {
                write!(
                    f,
                    "synthesis clock {clock_mhz} MHz is not a positive, \
                     finite frequency"
                )
            }
        }
    }
}

impl std::error::Error for DesignError {}

impl HlsConfig {
    /// Validate this configuration against an architecture.  Under
    /// resource strategy both reuse factors must exactly divide their
    /// mult counts; under latency strategy the binder unrolls fully and
    /// ignores the reuse factor, so no divisibility is required.
    pub fn validate(&self, arch: &Arch) -> Result<(), DesignError> {
        if !self.clock_mhz.is_finite() || self.clock_mhz <= 0.0 {
            return Err(DesignError::BadClock {
                clock_mhz: self.clock_mhz,
            });
        }
        if self.strategy == Strategy::Resource {
            let (mults_k, mults_r) = arch.rnn_mults_per_step();
            if mults_k % self.reuse.kernel != 0 {
                return Err(DesignError::ReuseNotDivisor {
                    arch_key: arch.key(),
                    which: "kernel",
                    reuse: self.reuse.kernel,
                    mults: mults_k,
                });
            }
            if mults_r % self.reuse.recurrent != 0 {
                return Err(DesignError::ReuseNotDivisor {
                    arch_key: arch.key(),
                    which: "recurrent",
                    reuse: self.reuse.recurrent,
                    mults: mults_r,
                });
            }
        }
        Ok(())
    }
}

/// One "synthesis run" of the analytical model.
#[derive(Debug, Clone)]
pub struct HlsDesign {
    pub arch: Arch,
    pub config: HlsConfig,
}

/// The analogue of a Vivado HLS synthesis report.
#[derive(Debug, Clone)]
pub struct SynthesisReport {
    pub arch_key: String,
    pub config: HlsConfig,
    pub timing: DesignTiming,
    pub resources: ResourceEstimate,
    pub device: Device,
    pub fits_device: bool,
}

impl HlsDesign {
    /// Construct a design, validating the configuration against the
    /// architecture ([`HlsConfig::validate`]).  A design that constructs
    /// always binds whole DSP lanes — non-divisor reuse factors are a
    /// typed [`DesignError`], not a silently fractional estimate.
    pub fn new(arch: Arch, config: HlsConfig) -> Result<Self, DesignError> {
        config.validate(&arch)?;
        Ok(Self { arch, config })
    }

    /// Run the scheduler + binder; errors on unsynthesizable configs.
    pub fn synthesize(&self) -> anyhow::Result<SynthesisReport> {
        self.synthesize_for(Device::for_benchmark(&self.arch.name))
    }

    /// Synthesize against an explicit target device.
    pub fn synthesize_for(
        &self,
        device: Device,
    ) -> anyhow::Result<SynthesisReport> {
        let timing = latency::schedule(&self.arch, &self.config)?;
        let resources = resource::estimate(&self.arch, &self.config);
        Ok(SynthesisReport {
            arch_key: self.arch.key(),
            config: self.config,
            timing,
            resources,
            device,
            fits_device: device.fits(&resources),
        })
    }
}

impl SynthesisReport {
    /// Compact one-line summary (used by the CLI sweep output).
    pub fn summary(&self) -> String {
        let (lut_u, ff_u, dsp_u, _b) = self.device.utilization(&self.resources);
        format!(
            "{} {} R={} {} {}: latency {:.2} µs, II {} cyc, \
             DSP {} ({:.0}%), LUT {} ({:.0}%), FF {} ({:.0}%), BRAM {}{}",
            self.arch_key,
            self.config.spec.label(),
            self.config.reuse.label(),
            self.config.strategy.label(),
            self.config.mode.label(),
            self.timing.latency_us,
            self.timing.ii_cycles,
            self.resources.dsp,
            dsp_u * 100.0,
            self.resources.lut,
            lut_u * 100.0,
            self.resources.ff,
            ff_u * 100.0,
            self.resources.bram_18k,
            if self.fits_device { "" } else { "  [DOES NOT FIT]" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::hls::{ReuseFactor, RnnMode, Strategy};
    use crate::model::{zoo, Cell};

    #[test]
    fn synthesize_produces_consistent_report() {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        let cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::new(6, 5),
        );
        let report = HlsDesign::new(arch, cfg).unwrap().synthesize().unwrap();
        assert_eq!(report.arch_key, "top_gru");
        assert_eq!(report.device.name, "KU115");
        assert!(report.fits_device);
        assert!(report.timing.ii_cycles <= report.timing.latency_cycles);
        assert!(report.summary().contains("top_gru"));
    }

    #[test]
    fn unsynthesizable_config_errors() {
        let arch = zoo::arch("quickdraw", Cell::Lstm).unwrap();
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::fully_parallel(),
        );
        cfg.strategy = Strategy::Latency;
        assert!(HlsDesign::new(arch, cfg).unwrap().synthesize().is_err());
    }

    /// The paper's bracketed-quirk rule as a typed error: top LSTM has
    /// 1600 recurrent mults, so reuse (60, 60) must be rejected (the
    /// paper uses `60[40]`) while (60, 40) constructs.
    #[test]
    fn non_divisor_reuse_is_a_typed_error() {
        let arch = zoo::arch("top", Cell::Lstm).unwrap();
        let cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::new(60, 60),
        );
        let err = HlsDesign::new(arch.clone(), cfg).unwrap_err();
        assert_eq!(
            err,
            super::DesignError::ReuseNotDivisor {
                arch_key: "top_lstm".into(),
                which: "recurrent",
                reuse: 60,
                mults: 1600,
            }
        );
        assert!(err.to_string().contains("recurrent reuse factor 60"));

        let ok = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::new(60, 40),
        );
        assert!(HlsDesign::new(arch, ok).is_ok());
    }

    /// Latency strategy unrolls fully and ignores the reuse factor, so
    /// divisibility is not required there.
    #[test]
    fn latency_strategy_skips_divisibility() {
        let arch = zoo::arch("top", Cell::Lstm).unwrap();
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::new(60, 60),
        );
        cfg.strategy = Strategy::Latency;
        assert!(HlsDesign::new(arch, cfg).is_ok());
    }

    #[test]
    fn bad_clock_is_a_typed_error() {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        for clock in [0.0, -200.0, f64::NAN, f64::INFINITY] {
            let mut cfg = HlsConfig::paper_default(
                FixedSpec::new(16, 6),
                ReuseFactor::new(6, 5),
            );
            cfg.clock_mhz = clock;
            assert!(matches!(
                HlsDesign::new(arch.clone(), cfg),
                Err(super::DesignError::BadClock { .. })
            ));
        }
    }

    #[test]
    fn nonfitting_design_is_flagged_not_erred() {
        let arch = zoo::arch("top", Cell::Lstm).unwrap();
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::fully_parallel(),
        );
        cfg.strategy = Strategy::Latency;
        cfg.mode = RnnMode::NonStatic;
        let report = HlsDesign::new(arch, cfg).unwrap().synthesize().unwrap();
        assert!(!report.fits_device);
        assert!(report.summary().contains("DOES NOT FIT"));
    }
}
