//! Design roll-up: architecture + configuration → full synthesis report.

use crate::model::Arch;

use super::device::Device;
use super::latency::{self, DesignTiming};
use super::resource::{self, ResourceEstimate};
use super::HlsConfig;

/// One "synthesis run" of the analytical model.
#[derive(Debug, Clone)]
pub struct HlsDesign {
    pub arch: Arch,
    pub config: HlsConfig,
}

/// The analogue of a Vivado HLS synthesis report.
#[derive(Debug, Clone)]
pub struct SynthesisReport {
    pub arch_key: String,
    pub config: HlsConfig,
    pub timing: DesignTiming,
    pub resources: ResourceEstimate,
    pub device: Device,
    pub fits_device: bool,
}

impl HlsDesign {
    pub fn new(arch: Arch, config: HlsConfig) -> Self {
        Self { arch, config }
    }

    /// Run the scheduler + binder; errors on unsynthesizable configs.
    pub fn synthesize(&self) -> anyhow::Result<SynthesisReport> {
        self.synthesize_for(Device::for_benchmark(&self.arch.name))
    }

    /// Synthesize against an explicit target device.
    pub fn synthesize_for(
        &self,
        device: Device,
    ) -> anyhow::Result<SynthesisReport> {
        let timing = latency::schedule(&self.arch, &self.config)?;
        let resources = resource::estimate(&self.arch, &self.config);
        Ok(SynthesisReport {
            arch_key: self.arch.key(),
            config: self.config,
            timing,
            resources,
            device,
            fits_device: device.fits(&resources),
        })
    }
}

impl SynthesisReport {
    /// Compact one-line summary (used by the CLI sweep output).
    pub fn summary(&self) -> String {
        let (lut_u, ff_u, dsp_u, _b) = self.device.utilization(&self.resources);
        format!(
            "{} {} R={} {} {}: latency {:.2} µs, II {} cyc, \
             DSP {} ({:.0}%), LUT {} ({:.0}%), FF {} ({:.0}%), BRAM {}{}",
            self.arch_key,
            self.config.spec.label(),
            self.config.reuse.label(),
            self.config.strategy.label(),
            self.config.mode.label(),
            self.timing.latency_us,
            self.timing.ii_cycles,
            self.resources.dsp,
            dsp_u * 100.0,
            self.resources.lut,
            lut_u * 100.0,
            self.resources.ff,
            ff_u * 100.0,
            self.resources.bram_18k,
            if self.fits_device { "" } else { "  [DOES NOT FIT]" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::hls::{ReuseFactor, RnnMode, Strategy};
    use crate::model::{zoo, Cell};

    #[test]
    fn synthesize_produces_consistent_report() {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        let cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::new(6, 5),
        );
        let report = HlsDesign::new(arch, cfg).synthesize().unwrap();
        assert_eq!(report.arch_key, "top_gru");
        assert_eq!(report.device.name, "KU115");
        assert!(report.fits_device);
        assert!(report.timing.ii_cycles <= report.timing.latency_cycles);
        assert!(report.summary().contains("top_gru"));
    }

    #[test]
    fn unsynthesizable_config_errors() {
        let arch = zoo::arch("quickdraw", Cell::Lstm).unwrap();
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::fully_parallel(),
        );
        cfg.strategy = Strategy::Latency;
        assert!(HlsDesign::new(arch, cfg).synthesize().is_err());
    }

    #[test]
    fn nonfitting_design_is_flagged_not_erred() {
        let arch = zoo::arch("top", Cell::Lstm).unwrap();
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::fully_parallel(),
        );
        cfg.strategy = Strategy::Latency;
        cfg.mode = RnnMode::NonStatic;
        let report = HlsDesign::new(arch, cfg).synthesize().unwrap();
        assert!(!report.fits_device);
        assert!(report.summary().contains("DOES NOT FIT"));
    }
}
