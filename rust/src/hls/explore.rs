//! Design-space exploration: Pareto search over the analytical HLS
//! model.
//!
//! The paper's central claim is that the implementation "can be
//! customized to meet specific design requirements for inference
//! latencies and FPGA resources".  [`paper`] only *replays* the
//! configurations the paper evaluated; this module *answers the budget
//! question* for arbitrary targets:
//!
//! 1. [`build_grid`] enumerates reuse × precision × strategy × clock ×
//!    RNN mode over a set of architectures, with divisibility-aware
//!    reuse enumeration ([`reuse_ladder`]) so every candidate is valid
//!    by construction ([`HlsDesign::new`] would reject anything else).
//! 2. [`evaluate`] runs every candidate through the scheduler + binder
//!    against one target [`Device`].
//! 3. [`join_accuracy`] annotates candidates of a checkpoint model with
//!    *measured* fixed-point AUC (`report::accuracy`), so the front
//!    answers "cheapest design that meets a latency budget *and* holds
//!    ≥ X AUC" — modeled cost joined with measured quality.
//! 4. [`pareto`] admits candidates through [`Filters`] (device fit is
//!    always required) and prunes to the Pareto front on (latency, II,
//!    DSP, LUT, FF, BRAM, quality); [`ExploreResult`] carries the full
//!    grid, the front, every pruned row's dominator, and budget queries
//!    ([`ExploreResult::cheapest_within`]).
//!
//! Each front row also serializes as a named backend candidate
//! ([`Candidate::backend_candidate`]): model key + `FixedSpec` + the
//! traffic class its modeled latency supports — the explorer doubles as
//! a scenario generator for the tiered serving layer.
//!
//! Methodology reference: Jia et al., *Analysis of Hardware Synthesis
//! Strategies for Machine Learning in Collider Trigger and Data
//! Acquisition* (arXiv 2411.11678).

use std::collections::BTreeSet;

use crate::coordinator::TierClass;
use crate::fixed::FixedSpec;
use crate::model::{zoo, Arch};

use super::design::HlsDesign;
use super::latency::{DesignTiming, Strategy, LATENCY_STRATEGY_PARAM_LIMIT};
use super::paper;
use super::resource::ResourceEstimate;
use super::{Device, HlsConfig, ReuseFactor, RnnMode};

/// Default precision ladder (total bits; integer bits follow the
/// paper's per-benchmark choice, [`spec_for`]).  Straddles the 18-bit
/// DSP cliff so the front shows both sides of it.
pub const DEFAULT_WIDTHS: [u32; 6] = [8, 12, 14, 16, 18, 20];

/// Default clock ladder in MHz: the paper's 200 MHz plus two faster
/// targets (each costs pipeline stages and retiming FFs,
/// `latency::clock_penalty`).
pub const DEFAULT_CLOCKS_MHZ: [f64; 3] = [200.0, 300.0, 400.0];

/// Modeled-latency threshold for the trigger tier (10 µs — the L1T
/// scale of the paper's §1 deployment story).  Front rows at or below
/// it are serving candidates for the trigger path, the rest for
/// offline.
pub const TRIGGER_BUDGET_NS: f64 = 10_000.0;

/// One exploration request: which architectures, against which device,
/// over which knob ladders.
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    pub archs: Vec<Arch>,
    pub device: Device,
    /// Total bit widths; integer bits via [`spec_for`].
    pub widths: Vec<u32>,
    pub clocks_mhz: Vec<f64>,
    pub strategies: Vec<Strategy>,
    pub modes: Vec<RnnMode>,
}

impl ExploreConfig {
    /// Full default ladders for a set of architectures.
    pub fn new(archs: Vec<Arch>, device: Device) -> Self {
        Self {
            archs,
            device,
            widths: DEFAULT_WIDTHS.to_vec(),
            clocks_mhz: DEFAULT_CLOCKS_MHZ.to_vec(),
            strategies: vec![Strategy::Latency, Strategy::Resource],
            modes: vec![RnnMode::Static, RnnMode::NonStatic],
        }
    }
}

/// The precision the explorer scans at a given total width: integer
/// bits follow the paper's per-benchmark Fig. 2 conclusion (6, or 10
/// for QuickDraw), clamped into a legal `FixedSpec`.
pub fn spec_for(benchmark: &str, width: u32) -> FixedSpec {
    let integer = paper::chosen_integer_bits(benchmark)
        .min(width.saturating_sub(1))
        .max(1);
    FixedSpec::new(width, integer)
}

/// All divisors of `n`, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            out.push(i);
            if i != n / i {
                out.push(n / i);
            }
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

/// The largest divisor of `n` at or below `target` (1 divides
/// everything, so this is total for `target >= 1`).
pub fn snap_down(n: usize, target: usize) -> usize {
    let mut best = 1;
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            if i <= target && i > best {
                best = i;
            }
            let j = n / i;
            if j <= target && j > best {
                best = j;
            }
        }
        i += 1;
    }
    best
}

/// Divisibility-aware reuse enumeration: a geometric ladder of target
/// factors from 1 up to `gates × hidden` (the paper's own maximum reuse
/// scale), each snapped down to the nearest valid divisor pair, unioned
/// with the paper's published grid for the three zoo benchmarks.  Every
/// returned pair divides both mult counts exactly, so the whole ladder
/// passes [`HlsConfig::validate`] by construction.
pub fn reuse_ladder(arch: &Arch) -> Vec<ReuseFactor> {
    let (mults_k, mults_r) = arch.rnn_mults_per_step();
    let cap = (arch.cell.gates() * arch.hidden_size).max(1);
    let mut set: BTreeSet<ReuseFactor> = BTreeSet::new();
    let mut target = 1usize;
    loop {
        set.insert(ReuseFactor::new(
            snap_down(mults_k, target),
            snap_down(mults_r, target),
        ));
        if target >= cap {
            break;
        }
        target = (target * 2).min(cap);
    }
    if zoo::BENCHMARKS.contains(&arch.name.as_str()) {
        set.extend(paper::reuse_grid(&arch.name, arch.cell));
    }
    set.into_iter().collect()
}

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub arch_key: String,
    pub config: HlsConfig,
    pub timing: DesignTiming,
    pub resources: ResourceEstimate,
    pub fits_device: bool,
    /// Measured fixed-point AUC, once joined ([`join_accuracy`]);
    /// `None` for models without a bundled checkpoint.
    pub auc: Option<f64>,
}

impl Candidate {
    pub fn latency_ns(&self) -> f64 {
        self.timing.latency_us * 1_000.0
    }

    pub fn ii_ns(&self) -> f64 {
        self.timing.ii_us * 1_000.0
    }

    /// The stable (model, precision, reuse, strategy, mode, clock) key
    /// every output surface sorts by, so JSON/CSV diff cleanly across
    /// commits.
    pub fn sort_key(&self) -> (String, u32, u32, usize, usize, u8, u8, u64) {
        (
            self.arch_key.clone(),
            self.config.spec.width,
            self.config.spec.integer,
            self.config.reuse.kernel,
            self.config.reuse.recurrent,
            match self.config.strategy {
                Strategy::Latency => 0,
                Strategy::Resource => 1,
            },
            match self.config.mode {
                RnnMode::Static => 0,
                RnnMode::NonStatic => 1,
            },
            (self.config.clock_mhz * 1_000.0).round() as u64,
        )
    }

    /// Stable row name, e.g. `top_gru_w16i6_r1x1_latency_static_c400` —
    /// the identity of the design as a serving scenario.
    pub fn name(&self) -> String {
        format!(
            "{}_w{}i{}_r{}x{}_{}_{}_c{}",
            self.arch_key,
            self.config.spec.width,
            self.config.spec.integer,
            self.config.reuse.kernel,
            self.config.reuse.recurrent,
            self.config.strategy.label(),
            match self.config.mode {
                RnnMode::Static => "static",
                RnnMode::NonStatic => "nonstatic",
            },
            self.config.clock_mhz.round() as u64,
        )
    }

    /// Minimization objectives: latency and II in time (comparable
    /// across clocks), then the four resource axes.
    fn cost_axes(&self) -> [f64; 6] {
        [
            self.latency_ns(),
            self.ii_ns(),
            self.resources.dsp as f64,
            self.resources.lut as f64,
            self.resources.ff as f64,
            self.resources.bram_18k as f64,
        ]
    }

    /// Pareto dominance: `self` is no worse than `other` on every cost
    /// axis *and* on quality, and strictly better on at least one.
    /// Quality is measured AUC when both rows carry one, precision
    /// width otherwise (wider ≈ more accurate, Fig. 2).  Rows of
    /// different models never dominate each other (a design for one
    /// physics task is not a substitute for another), and a row with
    /// measured AUC is never compared against one without.
    pub fn dominates(&self, other: &Candidate) -> bool {
        if self.arch_key != other.arch_key {
            return false;
        }
        let (q_self, q_other) = match (self.auc, other.auc) {
            (Some(a), Some(b)) => (a, b),
            (None, None) => (
                self.config.spec.width as f64,
                other.config.spec.width as f64,
            ),
            _ => return false,
        };
        if q_self < q_other {
            return false;
        }
        let a = self.cost_axes();
        let b = other.cost_axes();
        let mut strictly = q_self > q_other;
        for (x, y) in a.iter().zip(b.iter()) {
            if x > y {
                return false;
            }
            if x < y {
                strictly = true;
            }
        }
        strictly
    }

    /// The serving-bridge row: this design as a named backend candidate
    /// for the tiered serving layer.
    pub fn backend_candidate(&self) -> BackendCandidate {
        BackendCandidate {
            name: self.name(),
            model_key: self.arch_key.clone(),
            backend: "fixed",
            spec: self.config.spec,
            tier: if self.latency_ns() <= TRIGGER_BUDGET_NS {
                TierClass::Trigger
            } else {
                TierClass::Offline
            },
            latency_ns: self.latency_ns(),
        }
    }
}

/// A Pareto point as a serving scenario: the `nn::BackendSpec` registry
/// row that would serve it (the bit-accurate fixed engine stands in for
/// the FPGA datapath), the precision it runs at, and the traffic class
/// its modeled latency supports.
#[derive(Debug, Clone, PartialEq)]
pub struct BackendCandidate {
    /// Stable row name ([`Candidate::name`]).
    pub name: String,
    /// Model-zoo key routing requests to this design.
    pub model_key: String,
    /// Backend registry row, currently always `"fixed"`.
    pub backend: &'static str,
    pub spec: FixedSpec,
    pub tier: TierClass,
    pub latency_ns: f64,
}

/// The full candidate grid for one request: every (arch, width, clock,
/// strategy, mode, reuse) combination that is valid by construction —
/// divisor-snapped reuse under resource strategy, reuse (1, 1) under
/// latency strategy (which is skipped entirely for models at or over
/// the paper's 40k-parameter synthesis limit).
pub fn build_grid(cfg: &ExploreConfig) -> Vec<(Arch, HlsConfig)> {
    let fully_parallel = [ReuseFactor::fully_parallel()];
    let mut out = Vec::new();
    for arch in &cfg.archs {
        let ladder = reuse_ladder(arch);
        for &width in &cfg.widths {
            let spec = spec_for(&arch.name, width);
            for &clock_mhz in &cfg.clocks_mhz {
                for &strategy in &cfg.strategies {
                    if strategy == Strategy::Latency
                        && arch.param_count() >= LATENCY_STRATEGY_PARAM_LIMIT
                    {
                        continue;
                    }
                    let reuses: &[ReuseFactor] = match strategy {
                        Strategy::Latency => &fully_parallel,
                        Strategy::Resource => &ladder,
                    };
                    for &reuse in reuses {
                        for &mode in &cfg.modes {
                            out.push((
                                arch.clone(),
                                HlsConfig {
                                    spec,
                                    reuse,
                                    strategy,
                                    mode,
                                    clock_mhz,
                                },
                            ));
                        }
                    }
                }
            }
        }
    }
    out
}

/// Evaluate every grid point through the analytical model against the
/// target device.  The grid is valid by construction, so construction
/// or scheduling failures are real errors, not skips.  The result is
/// sorted by [`Candidate::sort_key`].
pub fn evaluate(cfg: &ExploreConfig) -> anyhow::Result<Vec<Candidate>> {
    let mut out = Vec::new();
    for (arch, hls_cfg) in build_grid(cfg) {
        let report =
            HlsDesign::new(arch, hls_cfg)?.synthesize_for(cfg.device)?;
        out.push(Candidate {
            arch_key: report.arch_key,
            config: report.config,
            timing: report.timing,
            resources: report.resources,
            fits_device: report.fits_device,
            auc: None,
        });
    }
    out.sort_by(|a, b| a.sort_key().cmp(&b.sort_key()));
    Ok(out)
}

/// Measured-accuracy annotation for one checkpoint model: per-spec AUC
/// from `report::accuracy`, keyed by precision.
#[derive(Debug, Clone)]
pub struct AccuracyJoin {
    /// Model-zoo key the checkpoint implements (e.g. `top_gru`).
    pub key: String,
    pub auc_float: f64,
    pub samples: usize,
    pub auc_by_spec: Vec<(FixedSpec, f64)>,
}

impl AccuracyJoin {
    pub fn auc_for(&self, spec: FixedSpec) -> Option<f64> {
        self.auc_by_spec
            .iter()
            .find(|(s, _)| *s == spec)
            .map(|(_, auc)| *auc)
    }
}

/// Annotate candidates of the joined model with measured AUC; other
/// models (and specs the join did not measure) stay unannotated.
pub fn join_accuracy(candidates: &mut [Candidate], join: &AccuracyJoin) {
    for c in candidates.iter_mut() {
        if c.arch_key == join.key && c.auc.is_none() {
            c.auc = join.auc_for(c.config.spec);
        }
    }
}

/// The distinct precision specs appearing among one model's candidates
/// — what an accuracy join has to measure.
pub fn distinct_specs(candidates: &[Candidate], key: &str) -> Vec<FixedSpec> {
    let set: BTreeSet<(u32, u32)> = candidates
        .iter()
        .filter(|c| c.arch_key == key)
        .map(|c| (c.config.spec.width, c.config.spec.integer))
        .collect();
    set.into_iter()
        .map(|(w, i)| FixedSpec::new(w, i))
        .collect()
}

/// Admission gates applied before pruning.  Device fit is always
/// required; `min_auc` demands *measured* accuracy (a row without an
/// AUC annotation never passes it).
#[derive(Debug, Clone, Copy, Default)]
pub struct Filters {
    pub budget_ns: Option<f64>,
    pub min_auc: Option<f64>,
}

impl Filters {
    pub fn admits(&self, c: &Candidate) -> bool {
        if !c.fits_device {
            return false;
        }
        let meets_budget = match self.budget_ns {
            Some(budget) => c.latency_ns() <= budget,
            None => true,
        };
        let meets_auc = match self.min_auc {
            Some(min) => c.auc.is_some_and(|a| a >= min),
            None => true,
        };
        meets_budget && meets_auc
    }
}

/// Record of one pruned row: which front row dominated it (both are
/// indices into [`ExploreResult::candidates`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dropped {
    pub index: usize,
    pub dominated_by: usize,
}

/// The result of one exploration: the full evaluated grid (stable
/// order), the admitted subset, its Pareto front, and every pruned
/// row's dominator.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    pub device: Device,
    pub filters: Filters,
    /// Every evaluated candidate, sorted by [`Candidate::sort_key`].
    pub candidates: Vec<Candidate>,
    /// Indices into `candidates`: rows passing device fit + filters.
    pub admitted: Vec<usize>,
    /// Indices into `candidates`: the Pareto front of the admitted set.
    pub front: Vec<usize>,
    /// Admitted rows pruned from the front, each naming a surviving
    /// dominator.
    pub dropped: Vec<Dropped>,
}

impl ExploreResult {
    /// Front rows in stable order.
    pub fn front_rows(&self) -> impl Iterator<Item = &Candidate> {
        self.front.iter().map(|&i| &self.candidates[i])
    }

    /// Lexicographic resource cost (DSP, then LUT, FF, BRAM): the total
    /// order "cheapest" ranks by.  DSPs lead because they are the
    /// scarce, non-substitutable resource in every §5 fit discussion.
    pub fn resource_cost(c: &Candidate) -> (u64, u64, u64, u64) {
        (
            c.resources.dsp,
            c.resources.lut,
            c.resources.ff,
            c.resources.bram_18k,
        )
    }

    /// The cheapest admitted design with modeled latency within
    /// `budget_ns`.  Scans the full admitted set — not just the front —
    /// so the answer is the true minimum over the grid; ties resolve to
    /// the first row in stable order.
    pub fn cheapest_within(&self, budget_ns: f64) -> Option<&Candidate> {
        self.admitted
            .iter()
            .map(|&i| &self.candidates[i])
            .filter(|c| c.latency_ns() <= budget_ns)
            .min_by(|a, b| Self::resource_cost(a).cmp(&Self::resource_cost(b)))
    }

    /// The fastest admitted design using at most `max_dsp` DSPs (the
    /// dual budget query); ties break toward cheaper, then stable
    /// order.
    pub fn fastest_within_dsp(&self, max_dsp: u64) -> Option<&Candidate> {
        self.admitted
            .iter()
            .map(|&i| &self.candidates[i])
            .filter(|c| c.resources.dsp <= max_dsp)
            .min_by(|a, b| {
                a.latency_ns()
                    .total_cmp(&b.latency_ns())
                    .then(Self::resource_cost(a).cmp(&Self::resource_cost(b)))
            })
    }

    /// Serving-bridge rows for the whole front, in stable order.
    pub fn backend_candidates(&self) -> Vec<BackendCandidate> {
        self.front_rows().map(|c| c.backend_candidate()).collect()
    }
}

/// Dominance-prune the admitted rows.  Every dropped row names a
/// dominator that is itself on the front: dominance is a strict partial
/// order (transitive within a model's comparable rows), so following
/// dominators upward from any pruned row terminates at an undominated
/// one that — by transitivity — also dominates it.
fn prune(
    candidates: &[Candidate],
    admitted: &[usize],
) -> (Vec<usize>, Vec<Dropped>) {
    let front: Vec<usize> = admitted
        .iter()
        .copied()
        .filter(|&i| {
            !admitted
                .iter()
                .any(|&j| j != i && candidates[j].dominates(&candidates[i]))
        })
        .collect();
    let mut dropped = Vec::new();
    for &i in admitted {
        if front.contains(&i) {
            continue;
        }
        let by = front
            .iter()
            .copied()
            .find(|&j| candidates[j].dominates(&candidates[i]))
            .expect("every dominated row has an undominated dominator");
        dropped.push(Dropped {
            index: i,
            dominated_by: by,
        });
    }
    (front, dropped)
}

/// Filter + prune already-evaluated (and possibly accuracy-joined)
/// candidates.  Exposed separately from [`explore`] so the CLI can join
/// accuracy between evaluation and pruning, and tests can drive
/// synthetic grids.
pub fn pareto(
    device: Device,
    mut candidates: Vec<Candidate>,
    filters: Filters,
) -> ExploreResult {
    candidates.sort_by(|a, b| a.sort_key().cmp(&b.sort_key()));
    let admitted: Vec<usize> = (0..candidates.len())
        .filter(|&i| filters.admits(&candidates[i]))
        .collect();
    let (front, dropped) = prune(&candidates, &admitted);
    ExploreResult {
        device,
        filters,
        candidates,
        admitted,
        front,
        dropped,
    }
}

/// Run the full exploration: evaluate the grid, apply accuracy joins,
/// filter, prune.
pub fn explore(
    cfg: &ExploreConfig,
    joins: &[AccuracyJoin],
    filters: Filters,
) -> anyhow::Result<ExploreResult> {
    let mut candidates = evaluate(cfg)?;
    for join in joins {
        join_accuracy(&mut candidates, join);
    }
    Ok(pareto(cfg.device, candidates, filters))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Cell;

    #[test]
    fn divisors_and_snap_down() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(snap_down(1200, 32), 30);
        assert_eq!(snap_down(1600, 60), 50);
        assert_eq!(snap_down(360, 7), 6);
        assert_eq!(snap_down(17, 16), 1);
        assert_eq!(snap_down(360, 360), 360);
    }

    #[test]
    fn ladder_divides_and_contains_paper_grid() {
        for arch in zoo::all_archs() {
            let (mults_k, mults_r) = arch.rnn_mults_per_step();
            let ladder = reuse_ladder(&arch);
            assert!(!ladder.is_empty());
            assert!(ladder.contains(&ReuseFactor::fully_parallel()));
            for reuse in &ladder {
                assert_eq!(mults_k % reuse.kernel, 0, "{}", arch.key());
                assert_eq!(mults_r % reuse.recurrent, 0, "{}", arch.key());
            }
            for reuse in paper::reuse_grid(&arch.name, arch.cell) {
                assert!(ladder.contains(&reuse), "{} {reuse:?}", arch.key());
            }
        }
    }

    #[test]
    fn spec_for_follows_paper_integer_choice() {
        assert_eq!(spec_for("top", 16), FixedSpec::new(16, 6));
        assert_eq!(spec_for("quickdraw", 16), FixedSpec::new(16, 10));
        // Clamped at narrow widths.
        assert_eq!(spec_for("top", 4), FixedSpec::new(4, 3));
        assert_eq!(spec_for("quickdraw", 8), FixedSpec::new(8, 7));
    }

    #[test]
    fn candidate_name_is_stable() {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::new(6, 5),
        );
        cfg.clock_mhz = 400.0;
        let c = Candidate {
            arch_key: arch.key(),
            config: cfg,
            timing: crate::hls::latency::schedule(&arch, &cfg).unwrap(),
            resources: crate::hls::resource::estimate(&arch, &cfg),
            fits_device: true,
            auc: None,
        };
        assert_eq!(c.name(), "top_gru_w16i6_r6x5_resource_static_c400");
        let bc = c.backend_candidate();
        assert_eq!(bc.backend, "fixed");
        assert_eq!(bc.model_key, "top_gru");
        assert_eq!(
            bc.tier == TierClass::Trigger,
            c.latency_ns() <= TRIGGER_BUDGET_NS
        );
    }

    #[test]
    fn grid_skips_latency_strategy_for_large_models() {
        let cfg = ExploreConfig::new(
            vec![zoo::arch("flavor", Cell::Lstm).unwrap()],
            Device::KU115,
        );
        for (_, hls_cfg) in build_grid(&cfg) {
            assert_eq!(hls_cfg.strategy, Strategy::Resource);
        }
    }

    #[test]
    fn mixed_auc_rows_never_dominate_each_other() {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        let cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::new(6, 5),
        );
        let base = Candidate {
            arch_key: arch.key(),
            config: cfg,
            timing: crate::hls::latency::schedule(&arch, &cfg).unwrap(),
            resources: crate::hls::resource::estimate(&arch, &cfg),
            fits_device: true,
            auc: Some(0.99),
        };
        let mut other = base.clone();
        other.auc = None;
        assert!(!base.dominates(&other));
        assert!(!other.dominates(&base));
        // Identical rows do not dominate each other either.
        assert!(!base.dominates(&base.clone()));
    }
}
