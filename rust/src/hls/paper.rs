//! The paper's exact evaluation grids and reported numbers.
//!
//! Keeping these in code lets every report/bench print paper-vs-model
//! side by side, and keeps the calibration tests honest.

use crate::model::Cell;

use super::ReuseFactor;

/// Reuse-factor columns of Tables 2–4, per benchmark and cell.  The LSTM
/// sometimes differs in the recurrent factor (the bracketed values in the
/// paper: `60, 60[40]` and `384, 384[256]`) because the recurrent mult
/// count must divide evenly: top LSTM has 1600 recurrent mults
/// (1600 % 60 ≠ 0 → 40) and QuickDraw LSTM 65536 (65536 % 384 ≠ 0 → 256).
pub fn reuse_grid(benchmark: &str, cell: Cell) -> Vec<ReuseFactor> {
    match (benchmark, cell) {
        ("top", Cell::Gru) => vec![
            ReuseFactor::new(6, 5),
            ReuseFactor::new(12, 10),
            ReuseFactor::new(30, 20),
            ReuseFactor::new(60, 60),
        ],
        ("top", Cell::Lstm) => vec![
            ReuseFactor::new(6, 5),
            ReuseFactor::new(12, 10),
            ReuseFactor::new(30, 20),
            ReuseFactor::new(60, 40),
        ],
        ("flavor", _) => vec![
            ReuseFactor::new(48, 40),
            ReuseFactor::new(90, 60),
            ReuseFactor::new(120, 120),
            ReuseFactor::new(240, 240),
        ],
        ("quickdraw", Cell::Gru) => vec![
            ReuseFactor::new(48, 32),
            ReuseFactor::new(96, 64),
            ReuseFactor::new(192, 128),
            ReuseFactor::new(384, 384),
        ],
        ("quickdraw", Cell::Lstm) => vec![
            ReuseFactor::new(48, 32),
            ReuseFactor::new(96, 64),
            ReuseFactor::new(192, 128),
            ReuseFactor::new(384, 256),
        ],
        _ => panic!("unknown benchmark {benchmark}"),
    }
}

/// One reported min–max latency band in µs (Tables 2–4).
#[derive(Debug, Clone, Copy)]
pub struct PaperLatency {
    pub reuse: ReuseFactor,
    pub min_us: f64,
    pub max_us: f64,
}

/// Paper Table 2 (top tagging), Table 3 (flavor), Table 4 (QuickDraw),
/// resource strategy columns.
pub fn latency_table(benchmark: &str, cell: Cell) -> Vec<PaperLatency> {
    let rows: &[(usize, usize, f64, f64)] = match (benchmark, cell) {
        ("top", Cell::Gru) => &[
            (6, 5, 2.4, 6.5),
            (12, 10, 3.2, 7.3),
            (30, 20, 5.0, 9.1),
            (60, 60, 8.0, 12.1),
        ],
        ("top", Cell::Lstm) => &[
            (6, 5, 2.7, 6.8),
            (12, 10, 3.5, 7.6),
            (30, 20, 5.3, 9.4),
            (60, 40, 8.3, 12.4),
        ],
        ("flavor", Cell::Gru) => &[
            (48, 40, 6.7, 24.8),
            (90, 60, 9.8, 27.9),
            (120, 120, 11.5, 29.6),
            (240, 240, 20.5, 38.6),
        ],
        ("flavor", Cell::Lstm) => &[
            (48, 40, 6.9, 25.0),
            (90, 60, 10.1, 28.2),
            (120, 120, 11.7, 29.8),
            (240, 240, 20.7, 38.8),
        ],
        ("quickdraw", Cell::Gru) => &[
            (48, 32, 35.4, 164.0),
            (96, 64, 59.4, 188.0),
            (192, 128, 107.0, 235.0),
            (384, 384, 203.0, 331.0),
        ],
        ("quickdraw", Cell::Lstm) => &[
            (48, 32, 35.9, 164.0),
            (96, 64, 59.9, 188.0),
            (192, 128, 107.0, 236.0),
            (384, 256, 203.0, 332.0),
        ],
        _ => panic!("unknown benchmark {benchmark}"),
    };
    rows.iter()
        .map(|&(rk, rr, lo, hi)| PaperLatency {
            reuse: ReuseFactor::new(rk, rr),
            min_us: lo,
            max_us: hi,
        })
        .collect()
}

/// Table 2 latency-strategy column (top tagging only): 1.7–1.7 µs.
pub const TOP_LATENCY_STRATEGY_US: f64 = 1.7;

/// Table 5: static vs non-static for the top-tagging models.
#[derive(Debug, Clone, Copy)]
pub struct PaperMode {
    pub cell: Cell,
    pub static_latency_us: f64,
    pub nonstatic_latency_us: f64,
    pub static_ii: u64,
    pub nonstatic_ii: u64,
}

pub const TABLE5: [PaperMode; 2] = [
    PaperMode {
        cell: Cell::Gru,
        static_latency_us: 1.7,
        nonstatic_latency_us: 1.6,
        static_ii: 315,
        nonstatic_ii: 1,
    },
    PaperMode {
        cell: Cell::Lstm,
        static_latency_us: 1.6,
        nonstatic_latency_us: 1.5,
        static_ii: 314,
        nonstatic_ii: 1,
    },
];

/// §5.2 throughput comparison for the QuickDraw LSTM (events/sec).
pub struct PaperThroughput {
    pub fpga_min: f64,
    pub fpga_max: f64,
    pub gpu_batch1: f64,
    pub gpu_batch10: f64,
    pub gpu_batch100: f64,
}

pub const QUICKDRAW_THROUGHPUT: PaperThroughput = PaperThroughput {
    fpga_min: 4_300.0,
    fpga_max: 9_700.0,
    gpu_batch1: 660.0,
    gpu_batch10: 7_700.0,
    gpu_batch100: 30_000.0,
};

/// Fig. 2 scan grid: integer bits × fractional bits.
pub const FIG2_INTEGER_BITS: [u32; 4] = [6, 8, 10, 12];
pub const FIG2_FRACTIONAL_BITS: std::ops::RangeInclusive<u32> = 2..=14;

/// The per-model integer-bit choice the paper settles on after Fig. 2
/// ("6 integer bits are sufficient [top/flavor], QuickDraw requires at
/// least 10").
pub fn chosen_integer_bits(benchmark: &str) -> u32 {
    match benchmark {
        "quickdraw" => 10,
        _ => 6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    /// The bracketed reuse quirks exist precisely because of mult counts.
    #[test]
    fn lstm_reuse_quirks_divide_mult_counts() {
        let top = zoo::arch("top", Cell::Lstm).unwrap();
        let (_, rec) = top.rnn_mults_per_step();
        assert_eq!(rec % 40, 0);
        assert_ne!(rec % 60, 0);

        let qd = zoo::arch("quickdraw", Cell::Lstm).unwrap();
        let (_, rec) = qd.rnn_mults_per_step();
        assert_eq!(rec % 256, 0);
        assert_ne!(rec % 384, 0);
    }

    /// GRU grids always divide too.
    #[test]
    fn gru_grid_divides_mult_counts() {
        for name in ["top", "flavor", "quickdraw"] {
            let a = zoo::arch(name, Cell::Gru).unwrap();
            let (k, r) = a.rnn_mults_per_step();
            for reuse in reuse_grid(name, Cell::Gru) {
                assert_eq!(k % reuse.kernel, 0, "{name} kernel {reuse:?}");
                assert_eq!(r % reuse.recurrent, 0, "{name} rec {reuse:?}");
            }
        }
    }

    #[test]
    fn latency_tables_have_four_columns_each() {
        for name in ["top", "flavor", "quickdraw"] {
            for cell in [Cell::Gru, Cell::Lstm] {
                let t = latency_table(name, cell);
                assert_eq!(t.len(), 4);
                for row in &t {
                    assert!(row.min_us <= row.max_us);
                }
            }
        }
    }

    #[test]
    fn fig2_grid_matches_paper() {
        assert_eq!(FIG2_INTEGER_BITS, [6, 8, 10, 12]);
        assert_eq!(chosen_integer_bits("top"), 6);
        assert_eq!(chosen_integer_bits("quickdraw"), 10);
    }
}
