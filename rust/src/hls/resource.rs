//! DSP / FF / LUT / BRAM estimation — the resource-binder half of the
//! Vivado HLS substitute.
//!
//! The scaling laws come straight from §5.2 of the paper:
//!
//! * **DSP**: "reuse is the number of multiplication operations each DSP
//!   block must do" → `DSP = mults / R`, and "the utilization remains
//!   flat until the precision exceeds the DSP input width" → ×2 above
//!   18 bits (DSP48E2 takes 18×27 operands).
//! * **FF/LUT**: "increase is roughly linear" in precision, and scales
//!   with the number of *instantiated* multiplier lanes (`mults / R`).
//! * **non-static**: "resource utilization that is a factor of the
//!   sequence length larger" for the RNN part.
//! * **GRU ≈ 3/4 LSTM** falls out of the 3-vs-4 gate matmul counts.

use crate::model::{Arch, OutputActivation};

use super::latency::{clock_penalty, Strategy};
use super::{HlsConfig, RnnMode};

/// DSP48E2 multiplier input width: one DSP per product at or below this
/// many bits, two above (the "DSP cliff" visible in Fig. 3).
pub const DSP_INPUT_WIDTH: u32 = 18;

// ---- calibrated fabric-cost constants (per multiplier lane) -------------
// LUTs per lane: base control/mux cost plus a per-bit term (partial
// products, carry logic).  FFs per lane: pipeline registers across the
// DSP + adder-tree stages, two registers per bit of the accumulation.

const LUT_PER_LANE_BASE: u64 = 20;
const LUT_PER_LANE_PER_BIT: u64 = 10;
const FF_PER_LANE_BASE: u64 = 20;
const FF_PER_LANE_PER_BIT: u64 = 8;
/// Extra fabric factor for latency strategy (fully unrolled control).
const LATENCY_STRATEGY_FABRIC: f64 = 1.1;
/// LUTs/FFs per element of elementwise state math, per bit.
const STATE_LUT_PER_BIT: u64 = 6;
const STATE_FF_PER_BIT: u64 = 4;

/// One synthesis resource estimate (same categories as Figs. 3–6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceEstimate {
    pub dsp: u64,
    pub lut: u64,
    pub ff: u64,
    pub bram_18k: u64,
}

impl ResourceEstimate {
    pub fn add(&self, other: &ResourceEstimate) -> ResourceEstimate {
        ResourceEstimate {
            dsp: self.dsp + other.dsp,
            lut: self.lut + other.lut,
            ff: self.ff + other.ff,
            bram_18k: self.bram_18k + other.bram_18k,
        }
    }

    pub fn scale(&self, k: u64) -> ResourceEstimate {
        ResourceEstimate {
            dsp: self.dsp * k,
            lut: self.lut * k,
            ff: self.ff * k,
            bram_18k: self.bram_18k * k,
        }
    }
}

/// DSPs needed per scalar product at this precision.
#[inline]
pub fn dsp_per_mult(width: u32) -> u64 {
    if width <= DSP_INPUT_WIDTH {
        1
    } else {
        2
    }
}

fn lane_cost(lanes: u64, width: u32, strategy: Strategy) -> (u64, u64, u64) {
    let w = width as u64;
    let mut lut = lanes * (LUT_PER_LANE_BASE + LUT_PER_LANE_PER_BIT * w);
    let mut ff = lanes * (FF_PER_LANE_BASE + FF_PER_LANE_PER_BIT * w);
    if strategy == Strategy::Latency {
        lut = (lut as f64 * LATENCY_STRATEGY_FABRIC) as u64;
        ff = (ff as f64 * LATENCY_STRATEGY_FABRIC) as u64;
    }
    let dsp = lanes * dsp_per_mult(width);
    (dsp, lut, ff)
}

/// Resources of the recurrent layer for ONE RNN block (static mode
/// instantiates exactly one of these; non-static one per step).
pub fn rnn_block(arch: &Arch, cfg: &HlsConfig) -> ResourceEstimate {
    let (mults_k, mults_r) = arch.rnn_mults_per_step();
    let (rk, rr) = match cfg.strategy {
        Strategy::Latency => (1, 1),
        Strategy::Resource => (cfg.reuse.kernel, cfg.reuse.recurrent),
    };
    let lanes_k = (mults_k as u64).div_ceil(rk as u64);
    let lanes_r = (mults_r as u64).div_ceil(rr as u64);
    let (dsp_k, lut_k, ff_k) = lane_cost(lanes_k, cfg.spec.width, cfg.strategy);
    let (dsp_r, lut_r, ff_r) = lane_cost(lanes_r, cfg.spec.width, cfg.strategy);

    // Retiming registers for clocks above the paper's 200 MHz: every
    // extra pipeline stage (latency::clock_penalty) is one register per
    // lane bit.  Zero at the paper clock, so Figs. 3–6 are untouched.
    let retime_ff = clock_penalty(cfg.clock_mhz)
        * (lanes_k + lanes_r)
        * cfg.spec.width as u64;

    // Elementwise state math (Hadamards, adds) + activation LUT ports.
    let g = arch.cell.gates() as u64;
    let h = arch.hidden_size as u64;
    let w = cfg.spec.width as u64;
    let state_lut = g * h * STATE_LUT_PER_BIT * w;
    let state_ff = g * h * STATE_FF_PER_BIT * w;

    // Weights live in BRAM under resource strategy; fully partitioned into
    // fabric under latency strategy (counted in the lane cost).
    let bram = match cfg.strategy {
        Strategy::Latency => g * 2, // activation tables only
        Strategy::Resource => {
            let weight_bits = arch.rnn_param_count() as u64 * w;
            weight_bits.div_ceil(18 * 1024) + g * 2
        }
    };

    ResourceEstimate {
        dsp: dsp_k + dsp_r,
        lut: lut_k + lut_r + state_lut,
        ff: ff_k + ff_r + state_ff + retime_ff,
        bram_18k: bram,
    }
}

/// Resources of the dense head (dense stack + output + softmax tables).
pub fn head(arch: &Arch, cfg: &HlsConfig) -> ResourceEstimate {
    let mut est = ResourceEstimate::default();
    let w = cfg.spec.width as u64;
    let mut fan_in = arch.hidden_size;
    for &size in arch
        .dense_sizes
        .iter()
        .chain(std::iter::once(&arch.output_size))
    {
        let mults = (fan_in * size) as u64;
        let reuse = match cfg.strategy {
            Strategy::Latency => 1,
            Strategy::Resource => (fan_in as u64).div_ceil(4),
        };
        let lanes = mults.div_ceil(reuse);
        let (dsp, lut, ff) = lane_cost(lanes, cfg.spec.width, cfg.strategy);
        est.dsp += dsp;
        est.lut += lut;
        est.ff += ff + clock_penalty(cfg.clock_mhz) * lanes * w;
        if cfg.strategy == Strategy::Resource {
            est.bram_18k += (mults * w).div_ceil(18 * 1024);
        }
        fan_in = size;
    }
    if arch.output_activation == OutputActivation::Softmax {
        // exp + reciprocal tables (the paper enlarges these for the
        // flavor/quickdraw models — reflected as extra BRAM + LUT).
        est.bram_18k += if arch.name == "top" { 2 } else { 8 };
        est.lut += 2_000;
    }
    est
}

/// Full-design estimate under the configured RNN mode.
pub fn estimate(arch: &Arch, cfg: &HlsConfig) -> ResourceEstimate {
    let block = rnn_block(arch, cfg);
    let rnn = match cfg.mode {
        RnnMode::Static => block,
        // §3: one block per sequence step.
        RnnMode::NonStatic => block.scale(arch.seq_len as u64),
    };
    rnn.add(&head(arch, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::hls::{HlsConfig, ReuseFactor, RnnMode};
    use crate::model::{zoo, Cell};

    fn cfg16(reuse: ReuseFactor) -> HlsConfig {
        HlsConfig::paper_default(FixedSpec::new(16, 6), reuse)
    }

    /// DSP = mults / R exactly, at the paper's own reuse points.  The
    /// LSTM (60, 40) quirk exists because 1600 % 60 != 0 — validated here.
    #[test]
    fn dsp_equals_mults_over_reuse() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        // GRU: kernel 6*60=360 mults, recurrent 20*60=1200 mults.
        let est = rnn_block(&a, &cfg16(ReuseFactor::new(6, 5)));
        assert_eq!(est.dsp, 360 / 6 + 1200 / 5);
        let est = rnn_block(&a, &cfg16(ReuseFactor::new(60, 60)));
        assert_eq!(est.dsp, 6 + 20);

        let a = zoo::arch("top", Cell::Lstm).unwrap();
        // LSTM: kernel 480, recurrent 1600; 1600/40 = 40 (the "[40]").
        let est = rnn_block(&a, &cfg16(ReuseFactor::new(60, 40)));
        assert_eq!(est.dsp, 8 + 40);
        assert_eq!(1600 % 60, 40, "why the paper uses [40] for LSTM");
    }

    /// Fig. 3: DSPs double once precision exceeds the DSP input width.
    #[test]
    fn dsp_cliff_at_18_bits() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        let r = ReuseFactor::new(6, 5);
        let narrow = rnn_block(&a, &cfg16(r));
        let mut wide_cfg = cfg16(r);
        wide_cfg.spec = FixedSpec::new(20, 6);
        let wide = rnn_block(&a, &wide_cfg);
        assert_eq!(wide.dsp, 2 * narrow.dsp);
        assert_eq!(dsp_per_mult(18), 1);
        assert_eq!(dsp_per_mult(19), 2);
    }

    /// §5.2: "GRU models use approximately 1/4 less resources" (3:4 gates).
    #[test]
    fn gru_is_three_quarters_of_lstm() {
        let gru = zoo::arch("top", Cell::Gru).unwrap();
        let lstm = zoo::arch("top", Cell::Lstm).unwrap();
        let r = ReuseFactor::new(6, 5);
        let eg = rnn_block(&gru, &cfg16(r));
        let el = rnn_block(&lstm, &cfg16(r));
        let ratio = eg.dsp as f64 / el.dsp as f64;
        assert!((ratio - 0.75).abs() < 0.01, "dsp ratio {ratio}");
        let lut_ratio = eg.lut as f64 / el.lut as f64;
        assert!((lut_ratio - 0.75).abs() < 0.05, "lut ratio {lut_ratio}");
    }

    /// Figs. 4–5: FF and LUT grow monotonically with width...
    #[test]
    fn fabric_monotone_in_width() {
        let a = zoo::arch("flavor", Cell::Lstm).unwrap();
        let r = ReuseFactor::new(48, 40);
        let mut prev = 0;
        for width in [8u32, 12, 16, 20, 24] {
            let mut c = cfg16(r);
            c.spec = FixedSpec::new(width, 6);
            let est = estimate(&a, &c);
            assert!(est.lut > prev, "width {width}");
            prev = est.lut;
        }
    }

    /// ...and shrink monotonically with reuse.
    #[test]
    fn fabric_antimonotone_in_reuse() {
        let a = zoo::arch("flavor", Cell::Gru).unwrap();
        let mut prev = u64::MAX;
        for (rk, rr) in [(48, 40), (90, 60), (120, 120), (240, 240)] {
            let est = estimate(&a, &cfg16(ReuseFactor::new(rk, rr)));
            assert!(est.lut < prev && est.ff < prev);
            prev = est.lut;
        }
    }

    /// Fig. 6 / §5.3: non-static multiplies RNN resources by seq_len and
    /// "requires too many resources to be feasible" for moderate models.
    #[test]
    fn nonstatic_scales_with_seq_len() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        let mut c = cfg16(ReuseFactor::fully_parallel());
        c.strategy = Strategy::Latency;
        let stat = estimate(&a, &c);
        c.mode = RnnMode::NonStatic;
        let non = estimate(&a, &c);
        let head_est = head(&a, &c);
        let ratio = (non.dsp - head_est.dsp) as f64
            / (stat.dsp - head_est.dsp) as f64;
        assert!((ratio - a.seq_len as f64).abs() < 1e-9, "ratio {ratio}");
    }

    /// §5.2: top tagging at full quantized performance (W=16) fits one
    /// VU9P SLR; flavor is "slightly larger"; non-static top at W=16
    /// blows the DSP budget (only very small widths fit, §5.3).
    #[test]
    fn device_fit_statements() {
        use crate::hls::Device;
        let top = zoo::arch("top", Cell::Lstm).unwrap();
        let est = estimate(&top, &cfg16(ReuseFactor::new(6, 5)));
        assert!(Device::VU9P_SLR.fits(&est), "top should fit 1 SLR: {est:?}");

        let flavor = zoo::arch("flavor", Cell::Gru).unwrap();
        let est_f = estimate(&flavor, &cfg16(ReuseFactor::new(48, 40)));
        assert!(est_f.dsp <= Device::VU9P_SLR.dsps, "flavor DSPs fit");
        assert!(est_f.dsp > est.dsp, "flavor larger than top");

        let mut non = cfg16(ReuseFactor::fully_parallel());
        non.mode = RnnMode::NonStatic;
        non.strategy = Strategy::Latency;
        let est_n = estimate(&top, &non);
        assert!(
            !Device::KU115.fits(&est_n),
            "non-static top at W=16 must exceed the chip: {est_n:?}"
        );
    }

    /// The clock knob is a real trade: above 200 MHz the retiming
    /// registers cost FFs (and only FFs), while at the paper clock the
    /// calibration is bit-identical.
    #[test]
    fn clock_retiming_costs_ffs_only() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        let base = cfg16(ReuseFactor::new(6, 5));
        let mut fast = base;
        fast.clock_mhz = 400.0;
        let e200 = estimate(&a, &base);
        let e400 = estimate(&a, &fast);
        assert!(e400.ff > e200.ff, "retiming must cost FFs");
        assert_eq!(e400.dsp, e200.dsp);
        assert_eq!(e400.lut, e200.lut);
        assert_eq!(e400.bram_18k, e200.bram_18k);
    }

    /// QuickDraw at maximal quantized performance targets a U250 (§5.2).
    #[test]
    fn quickdraw_fits_u250_at_moderate_reuse() {
        use crate::hls::Device;
        let a = zoo::arch("quickdraw", Cell::Lstm).unwrap();
        let est = estimate(&a, &cfg16(ReuseFactor::new(48, 32)));
        assert!(Device::U250.fits(&est), "{est:?}");
    }
}
