//! Target-device database: the three Xilinx parts of the paper's §5.

/// Resource budget of one FPGA (or one SLR of it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Device {
    pub name: &'static str,
    pub part: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub dsps: u64,
    /// BRAM expressed in 18 Kb blocks.
    pub bram_18k: u64,
}

impl Device {
    /// Xilinx Kintex UltraScale KU115 — target for the top-tagging and
    /// flavor-tagging models (§5).
    pub const KU115: Device = Device {
        name: "KU115",
        part: "xcku115-flvb2104-2-i",
        luts: 663_360,
        ffs: 1_326_720,
        dsps: 5_520,
        bram_18k: 4_320,
    };

    /// Xilinx Alveo U250 — target for the QuickDraw models (§5).
    pub const U250: Device = Device {
        name: "U250",
        part: "xcu250-figd2104-2-e",
        luts: 1_728_000,
        ffs: 3_456_000,
        dsps: 12_288,
        bram_18k: 5_376,
    };

    /// One SLR of a Virtex UltraScale+ VU9P — the CMS L1T Phase-2 upgrade
    /// device the paper checks the small models against (§5.2).
    pub const VU9P_SLR: Device = Device {
        name: "VU9P (1 SLR)",
        part: "xcvu9p (1/3)",
        luts: 394_080,
        ffs: 788_160,
        dsps: 2_280,
        bram_18k: 1_440,
    };

    pub fn by_name(name: &str) -> anyhow::Result<Device> {
        match name.to_ascii_lowercase().as_str() {
            "ku115" => Ok(Self::KU115),
            "u250" => Ok(Self::U250),
            "vu9p" | "vu9p_slr" | "vu9p-slr" => Ok(Self::VU9P_SLR),
            other => anyhow::bail!(
                "unknown device {other:?} (want ku115|u250|vu9p_slr)"
            ),
        }
    }

    /// The paper's device assignment per benchmark (§5).
    pub fn for_benchmark(benchmark: &str) -> Device {
        match benchmark {
            "quickdraw" => Self::U250,
            _ => Self::KU115,
        }
    }

    /// Does an estimate fit this device?
    pub fn fits(&self, est: &super::ResourceEstimate) -> bool {
        est.dsp <= self.dsps
            && est.lut <= self.luts
            && est.ff <= self.ffs
            && est.bram_18k <= self.bram_18k
    }

    /// Utilization fractions `(lut, ff, dsp, bram)` of an estimate.
    pub fn utilization(
        &self,
        est: &super::ResourceEstimate,
    ) -> (f64, f64, f64, f64) {
        (
            est.lut as f64 / self.luts as f64,
            est.ff as f64 / self.ffs as f64,
            est.dsp as f64 / self.dsps as f64,
            est.bram_18k as f64 / self.bram_18k as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(Device::by_name("KU115").unwrap().dsps, 5_520);
        assert_eq!(Device::by_name("u250").unwrap().name, "U250");
        assert!(Device::by_name("vu13p").is_err());
    }

    #[test]
    fn paper_benchmark_assignment() {
        assert_eq!(Device::for_benchmark("top").name, "KU115");
        assert_eq!(Device::for_benchmark("flavor").name, "KU115");
        assert_eq!(Device::for_benchmark("quickdraw").name, "U250");
    }

    #[test]
    fn slr_is_a_third_of_vu9p_ballpark() {
        // VU9P has ~1.18M LUTs, 6840 DSPs over 3 SLRs.
        assert!(Device::VU9P_SLR.dsps * 3 == 6_840);
        assert!(Device::VU9P_SLR.luts * 3 > 1_100_000);
    }
}
