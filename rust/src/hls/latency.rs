//! Cycle-level latency / initiation-interval scheduler.
//!
//! Implements the scaling laws the paper states and the anchor points it
//! reports (see module docs on [`crate::hls`]).  The unit is clock cycles
//! at the configured synthesis clock (paper: 200 MHz → 5 ns).
//!
//! The per-step recurrence cannot be pipelined across steps in static
//! mode (h_t depends on h_{t-1}), so:
//!
//! ```text
//! II(static)       = seq_len × cell_II          (§3: "II equals latency")
//! latency(static)  = II(static) + head
//! II(non-static)   = II of ONE block            (§3, Table 5)
//! ```
//!
//! with `cell_II = reuse.max() + pipeline_depth + width_penalty` under
//! resource strategy (DSPs are time-multiplexed `R` times per step) and
//! `cell_II = pipeline_depth − 2` under latency strategy (fully unrolled
//! multiplier array, II limited only by the state feedback).

use crate::model::Arch;

use super::{HlsConfig, ReuseFactor, RnnMode};

/// hls4ml synthesis strategy (§5.2 "Parallelization").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Strategy {
    /// Minimize latency (fully parallel).  Only synthesizable for small
    /// models — the paper: "for large models with 40k or more trainable
    /// parameters ... resource strategy must be used".
    Latency,
    /// Minimize resources by time-multiplexing DSPs (reuse factor).
    Resource,
}

impl Strategy {
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Latency => "latency",
            Strategy::Resource => "resource",
        }
    }
}

/// Parameter-count threshold above which latency strategy fails to
/// synthesize (paper §5.2: "models with 40k or more trainable
/// parameters").
pub const LATENCY_STRATEGY_PARAM_LIMIT: usize = 40_000;

/// Width band scanned by the paper's evaluation; min/max latencies in
/// Tables 2–4 correspond to the ends of this band.
pub const WIDTH_LO: u32 = 8;
pub const WIDTH_HI: u32 = 26;

// ---- calibrated scheduler constants (see module docs) -------------------

/// The paper's synthesis clock (MHz): all calibration anchors hold at
/// this frequency, and [`clock_penalty`] is zero at or below it.
pub const PAPER_CLOCK_MHZ: f64 = 200.0;

/// Pipelined DSP multiplier latency (cycles).
pub const DSP_LATENCY: u64 = 4;
/// Activation LUT lookup + cast (cycles).
pub const ACT_LATENCY: u64 = 3;
/// State-update chain: two Hadamards + adds + state write (cycles).
pub const STATE_LATENCY: u64 = 6;

/// Adder-tree depth for a fan-in of `n` (⌈log₂ n⌉).
#[inline]
pub fn adder_tree_depth(n: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let mut depth = 0;
    let mut size = n - 1;
    while size > 0 {
        size >>= 1;
        depth += 1;
    }
    depth
}

/// Pipeline depth of one RNN state update, excluding DSP reuse: multiply,
/// reduce (fan-in `I + H` — kernel and recurrent products reduce in one
/// tree), activation, state math.
pub fn cell_pipeline_depth(arch: &Arch) -> u64 {
    DSP_LATENCY
        + adder_tree_depth(arch.input_size + arch.hidden_size + 1)
        + ACT_LATENCY
        + STATE_LATENCY
}

/// Extra cycles/step from wide datatypes: above `WIDTH_LO` bits, wide
/// accumulation and elementwise chains serialize with the hidden size.
/// Calibrated to the paper's min–max latency bands (≈ `2·H` cycles/step
/// across the full width sweep for all three benchmarks).
pub fn width_penalty(arch: &Arch, width: u32) -> u64 {
    let over = width.saturating_sub(WIDTH_LO) as u64;
    let span = (WIDTH_HI - WIDTH_LO) as u64;
    (2 * arch.hidden_size as u64 * over).div_ceil(span)
}

/// Extra pipeline stages needed to close timing above the paper's
/// 200 MHz synthesis clock: each additional 100 MHz (or part thereof)
/// deepens the datapath by one register stage — the standard
/// shorter-critical-path/deeper-pipeline trade.  Zero at or below
/// [`PAPER_CLOCK_MHZ`], so every calibration anchor is untouched;
/// the matching register cost lands in the resource binder
/// ([`super::resource`]).
pub fn clock_penalty(clock_mhz: f64) -> u64 {
    if clock_mhz <= PAPER_CLOCK_MHZ {
        0
    } else {
        ((clock_mhz - PAPER_CLOCK_MHZ) / 100.0).ceil() as u64
    }
}

/// II of a single RNN block (one state update).
pub fn cell_ii(arch: &Arch, cfg: &HlsConfig) -> u64 {
    let retime = clock_penalty(cfg.clock_mhz);
    match cfg.strategy {
        Strategy::Latency => cell_pipeline_depth(arch) - 2 + retime,
        Strategy::Resource => {
            cfg.reuse.max_factor() as u64
                + cell_pipeline_depth(arch)
                + width_penalty(arch, cfg.spec.width)
                + retime
        }
    }
}

/// Cycles through the dense head (hidden → dense stack → output), with
/// its activations; resource strategy time-multiplexes each dense layer
/// with a fan-in-proportional reuse.
pub fn head_latency(arch: &Arch, cfg: &HlsConfig) -> u64 {
    let mut cycles = 0u64;
    let mut fan_in = arch.hidden_size;
    for &size in arch
        .dense_sizes
        .iter()
        .chain(std::iter::once(&arch.output_size))
    {
        let reuse_head = match cfg.strategy {
            Strategy::Latency => 1,
            Strategy::Resource => (fan_in as u64).div_ceil(4),
        };
        cycles += DSP_LATENCY
            + adder_tree_depth(fan_in + 1)
            + reuse_head
            + 1
            + clock_penalty(cfg.clock_mhz);
        fan_in = size;
    }
    cycles += match arch.output_activation {
        crate::model::OutputActivation::Sigmoid => ACT_LATENCY,
        // hls4ml softmax: exp LUT + sum + reciprocal LUT + multiply.
        crate::model::OutputActivation::Softmax => 30,
    };
    cycles
}

/// Full timing report for one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignTiming {
    pub latency_cycles: u64,
    pub ii_cycles: u64,
    pub latency_us: f64,
    pub ii_us: f64,
    /// Inferences per second at the synthesis clock, `clock / II`.
    pub throughput_hz: f64,
}

/// Schedule one design.  Errors if the configuration is unsynthesizable
/// (latency strategy on a ≥ 40k-parameter model, §5.2).
pub fn schedule(arch: &Arch, cfg: &HlsConfig) -> anyhow::Result<DesignTiming> {
    if cfg.strategy == Strategy::Latency
        && arch.param_count() >= LATENCY_STRATEGY_PARAM_LIMIT
    {
        anyhow::bail!(
            "{}: latency strategy does not synthesize for models with >= \
             {LATENCY_STRATEGY_PARAM_LIMIT} parameters ({} here) — use \
             resource strategy (paper §5.2)",
            arch.key(),
            arch.param_count()
        );
    }
    let seq = arch.seq_len as u64;
    let cell = cell_ii(arch, cfg);
    let head = head_latency(arch, cfg);
    let (latency_cycles, ii_cycles) = match cfg.mode {
        RnnMode::Static => (seq * cell + head, seq * cell),
        RnnMode::NonStatic => {
            // Blocks stream: the state hop between blocks saves the
            // feedback cycle; a new inference enters once block 0 frees.
            let latency = seq * (cell - 1) + head;
            let ii = match cfg.strategy {
                Strategy::Latency => 1,
                Strategy::Resource => cfg.reuse.max_factor() as u64,
            };
            (latency, ii)
        }
    };
    let cycle_us = cfg.cycle_us();
    Ok(DesignTiming {
        latency_cycles,
        ii_cycles,
        latency_us: latency_cycles as f64 * cycle_us,
        ii_us: ii_cycles as f64 * cycle_us,
        throughput_hz: cfg.clock_mhz * 1e6 / ii_cycles as f64,
    })
}

/// §3's *unimplemented* future-work option, built here as an extension:
/// "multiple inferences can be cached during static mode when the
/// initiation interval of a single RNN block is less than its latency,
/// thus allowing for higher throughput."
///
/// A single block's own II is bounded by DSP reuse (`R` under resource
/// strategy, 1 under latency strategy) while its *latency* is the full
/// `cell_II`; the gap lets `cell_II / block_II` distinct inferences
/// time-share the block.  Returns the improved timing plus the number of
/// in-flight inferences the block state cache must hold.
pub fn schedule_cached_static(
    arch: &Arch,
    cfg: &HlsConfig,
) -> anyhow::Result<(DesignTiming, u64)> {
    anyhow::ensure!(
        cfg.mode == RnnMode::Static,
        "inference caching applies to static mode only"
    );
    let base = schedule(arch, cfg)?;
    let cell = cell_ii(arch, cfg);
    let block_ii = match cfg.strategy {
        Strategy::Latency => 1,
        Strategy::Resource => cfg.reuse.max_factor() as u64,
    };
    let in_flight = (cell / block_ii).max(1);
    let ii_cycles = (arch.seq_len as u64 * cell).div_ceil(in_flight);
    let cycle_us = cfg.cycle_us();
    Ok((
        DesignTiming {
            latency_cycles: base.latency_cycles, // per-inference latency unchanged
            ii_cycles,
            latency_us: base.latency_us,
            ii_us: ii_cycles as f64 * cycle_us,
            throughput_hz: cfg.clock_mhz * 1e6 / ii_cycles as f64,
        },
        in_flight,
    ))
}

/// Min/max latency in µs over the paper's width band (the format of
/// Tables 2–4).
pub fn latency_band(
    arch: &Arch,
    reuse: ReuseFactor,
    strategy: Strategy,
) -> anyhow::Result<(f64, f64)> {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for width in [WIDTH_LO, WIDTH_HI] {
        let integer = 6.min(width - 1).max(1);
        let mut cfg = HlsConfig::paper_default(
            crate::fixed::FixedSpec::new(width, integer),
            reuse,
        );
        cfg.strategy = strategy;
        let t = schedule(arch, &cfg)?;
        lo = lo.min(t.latency_us);
        hi = hi.max(t.latency_us);
    }
    Ok((lo, hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::model::{zoo, Cell};

    fn cfg(
        spec: FixedSpec,
        reuse: ReuseFactor,
        strategy: Strategy,
        mode: RnnMode,
    ) -> HlsConfig {
        HlsConfig {
            spec,
            reuse,
            strategy,
            mode,
            clock_mhz: 200.0,
        }
    }

    #[test]
    fn adder_tree_depths() {
        assert_eq!(adder_tree_depth(1), 0);
        assert_eq!(adder_tree_depth(2), 1);
        assert_eq!(adder_tree_depth(26), 5);
        assert_eq!(adder_tree_depth(127), 7);
        assert_eq!(adder_tree_depth(128), 7);
        assert_eq!(adder_tree_depth(129), 8);
    }

    /// Table 5 anchor: top-tagging static II ≈ 315 cycles (GRU) with
    /// latency strategy; latency ≈ 1.7 µs.
    #[test]
    fn top_static_ii_near_paper() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        let c = cfg(
            FixedSpec::new(16, 6),
            ReuseFactor::fully_parallel(),
            Strategy::Latency,
            RnnMode::Static,
        );
        let t = schedule(&a, &c).unwrap();
        // paper: II 315, latency 340 (1.7 µs)
        assert!(
            (t.ii_cycles as i64 - 315).abs() <= 16,
            "II {} vs paper 315",
            t.ii_cycles
        );
        assert!(
            (t.latency_us - 1.7).abs() < 0.2,
            "latency {} vs paper 1.7",
            t.latency_us
        );
    }

    /// Table 5: non-static II collapses to 1 with latency strategy.
    #[test]
    fn top_nonstatic_ii_is_one() {
        let a = zoo::arch("top", Cell::Lstm).unwrap();
        let c = cfg(
            FixedSpec::new(10, 6),
            ReuseFactor::fully_parallel(),
            Strategy::Latency,
            RnnMode::NonStatic,
        );
        let t = schedule(&a, &c).unwrap();
        assert_eq!(t.ii_cycles, 1);
        // >300x throughput win over static (paper: "more than 300").
        let stat = schedule(
            &a,
            &cfg(
                FixedSpec::new(10, 6),
                ReuseFactor::fully_parallel(),
                Strategy::Latency,
                RnnMode::Static,
            ),
        )
        .unwrap();
        assert!(stat.ii_cycles / t.ii_cycles > 300);
    }

    /// Table 2 anchors: top-tagging resource-strategy minimum latencies
    /// grow ≈ 1 cycle/step per reuse unit: 2.4 µs @ (6,5) → 8.0 @ (60,60).
    #[test]
    fn top_resource_latency_tracks_reuse() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        let paper = [
            (ReuseFactor::new(6, 5), 2.4),
            (ReuseFactor::new(12, 10), 3.2),
            (ReuseFactor::new(30, 20), 5.0),
            (ReuseFactor::new(60, 60), 8.0),
        ];
        for (reuse, want_us) in paper {
            let c = cfg(
                FixedSpec::new(8, 6),
                reuse,
                Strategy::Resource,
                RnnMode::Static,
            );
            let got = schedule(&a, &c).unwrap().latency_us;
            let err = (got - want_us).abs() / want_us;
            assert!(
                err < 0.15,
                "R={} got {got:.2} µs vs paper {want_us} µs",
                reuse.label()
            );
        }
    }

    /// Table 4 anchors: QuickDraw minimum latencies.
    #[test]
    fn quickdraw_resource_latency_matches_table4() {
        let a = zoo::arch("quickdraw", Cell::Gru).unwrap();
        let paper = [
            (ReuseFactor::new(48, 32), 35.4),
            (ReuseFactor::new(96, 64), 59.4),
            (ReuseFactor::new(192, 128), 107.0),
            (ReuseFactor::new(384, 384), 203.0),
        ];
        for (reuse, want_us) in paper {
            let c = cfg(
                FixedSpec::new(8, 6),
                reuse,
                Strategy::Resource,
                RnnMode::Static,
            );
            let got = schedule(&a, &c).unwrap().latency_us;
            let err = (got - want_us).abs() / want_us;
            assert!(
                err < 0.1,
                "R={} got {got:.2} µs vs paper {want_us} µs",
                reuse.label()
            );
        }
    }

    /// Table 3 anchors: flavor tagging (±20% — the head model is coarser).
    #[test]
    fn flavor_resource_latency_near_table3() {
        let a = zoo::arch("flavor", Cell::Gru).unwrap();
        let paper = [
            (ReuseFactor::new(48, 40), 6.7),
            (ReuseFactor::new(90, 60), 9.8),
            (ReuseFactor::new(120, 120), 11.5),
            (ReuseFactor::new(240, 240), 20.5),
        ];
        for (reuse, want_us) in paper {
            let c = cfg(
                FixedSpec::new(8, 6),
                reuse,
                Strategy::Resource,
                RnnMode::Static,
            );
            let got = schedule(&a, &c).unwrap().latency_us;
            let err = (got - want_us).abs() / want_us;
            assert!(
                err < 0.2,
                "R={} got {got:.2} µs vs paper {want_us} µs",
                reuse.label()
            );
        }
    }

    #[test]
    fn latency_strategy_rejected_for_large_models() {
        let a = zoo::arch("flavor", Cell::Lstm).unwrap(); // 67k params
        let c = cfg(
            FixedSpec::new(16, 6),
            ReuseFactor::fully_parallel(),
            Strategy::Latency,
            RnnMode::Static,
        );
        assert!(schedule(&a, &c).is_err());
    }

    #[test]
    fn width_increases_latency_in_resource_strategy() {
        let a = zoo::arch("top", Cell::Lstm).unwrap();
        let narrow = cfg(
            FixedSpec::new(8, 6),
            ReuseFactor::new(6, 5),
            Strategy::Resource,
            RnnMode::Static,
        );
        let wide = cfg(
            FixedSpec::new(26, 6),
            ReuseFactor::new(6, 5),
            Strategy::Resource,
            RnnMode::Static,
        );
        let t_n = schedule(&a, &narrow).unwrap();
        let t_w = schedule(&a, &wide).unwrap();
        assert!(t_w.latency_cycles > t_n.latency_cycles);
        // Table 2 band: max − min ≈ 4.1 µs for top tagging.
        let band = t_w.latency_us - t_n.latency_us;
        assert!((band - 4.1).abs() < 0.6, "band {band:.2} µs vs paper 4.1");
    }

    #[test]
    fn ii_never_exceeds_latency() {
        for a in zoo::all_archs() {
            for mode in [RnnMode::Static, RnnMode::NonStatic] {
                let c = cfg(
                    FixedSpec::new(16, 6),
                    ReuseFactor::new(12, 10),
                    Strategy::Resource,
                    mode,
                );
                let t = schedule(&a, &c).unwrap();
                assert!(t.ii_cycles <= t.latency_cycles, "{} {mode:?}", a.key());
            }
        }
    }

    /// Extension (§3 future work): cached static mode must improve II
    /// without changing per-inference latency, bounded by non-static II.
    #[test]
    fn cached_static_between_static_and_nonstatic() {
        for a in zoo::all_archs() {
            let c = cfg(
                FixedSpec::new(16, 6),
                ReuseFactor::new(12, 10),
                Strategy::Resource,
                RnnMode::Static,
            );
            let plain = schedule(&a, &c).unwrap();
            let (cached, in_flight) = schedule_cached_static(&a, &c).unwrap();
            assert!(in_flight >= 1);
            assert_eq!(cached.latency_cycles, plain.latency_cycles);
            assert!(cached.ii_cycles <= plain.ii_cycles, "{}", a.key());
            let mut nc = c;
            nc.mode = RnnMode::NonStatic;
            let non = schedule(&a, &nc).unwrap();
            assert!(
                cached.ii_cycles >= non.ii_cycles,
                "{}: cached {} vs non-static {}",
                a.key(),
                cached.ii_cycles,
                non.ii_cycles
            );
        }
    }

    #[test]
    fn cached_static_rejects_nonstatic_mode() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        let c = cfg(
            FixedSpec::new(16, 6),
            ReuseFactor::new(6, 5),
            Strategy::Resource,
            RnnMode::NonStatic,
        );
        assert!(schedule_cached_static(&a, &c).is_err());
    }

    #[test]
    fn clock_penalty_is_zero_at_paper_clock() {
        assert_eq!(clock_penalty(100.0), 0);
        assert_eq!(clock_penalty(200.0), 0);
        assert_eq!(clock_penalty(201.0), 1);
        assert_eq!(clock_penalty(300.0), 1);
        assert_eq!(clock_penalty(400.0), 2);
    }

    /// Raising the clock costs cycles (deeper pipeline) but still wins
    /// wall-clock time: the design-space explorer's clock knob.
    #[test]
    fn higher_clock_adds_cycles_but_cuts_latency() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        let mut c = cfg(
            FixedSpec::new(16, 6),
            ReuseFactor::fully_parallel(),
            Strategy::Latency,
            RnnMode::Static,
        );
        let base = schedule(&a, &c).unwrap();
        c.clock_mhz = 400.0;
        let fast = schedule(&a, &c).unwrap();
        assert!(fast.latency_cycles > base.latency_cycles);
        assert!(fast.latency_us < base.latency_us);
        assert!(fast.ii_us < base.ii_us);
        // The acceptance-scale point: a 400 MHz latency-strategy top GRU
        // schedules inside a 1 µs budget.
        assert!(fast.latency_us <= 1.0, "latency {} µs", fast.latency_us);
    }

    #[test]
    fn latency_band_is_ordered() {
        let a = zoo::arch("top", Cell::Gru).unwrap();
        let (lo, hi) =
            latency_band(&a, ReuseFactor::new(6, 5), Strategy::Resource).unwrap();
        assert!(lo <= hi);
        assert!(lo > 0.0);
    }
}
