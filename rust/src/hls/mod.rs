//! Analytical HLS model — the Vivado HLS 2019.2 substitute.
//!
//! The paper's Tables 2–5 and Figs. 3–6 are *HLS synthesis estimates*:
//! latency/II from the scheduler and DSP/FF/LUT/BRAM from the resource
//! binder, as functions of (bit width, reuse factor, strategy, RNN mode).
//! We have no Vivado, so this module implements those estimates as an
//! explicit, calibrated analytical model (DESIGN.md §Hardware
//! substitution):
//!
//! * [`latency`] — cycle-level scheduling: per-step cell II, sequence
//!   latency, initiation interval, static vs non-static pipelining.
//! * [`resource`] — DSP/FF/LUT/BRAM binding: `DSP = mults / reuse`
//!   (the paper's definition of reuse), linear-in-width fabric costs,
//!   the DSP-input-width cliff at 18 bits, LUT activation tables.
//! * [`device`] — the three target parts used in the paper (KU115,
//!   Alveo U250, one SLR of a VU9P) with their resource budgets.
//! * [`design`] — roll-up: an [`design::HlsDesign`] combines an
//!   architecture with an [`HlsConfig`] and yields the full synthesis
//!   report, including device-fit checks.
//! * [`paper`] — the exact configuration grids of the paper's evaluation
//!   (reuse-factor pairs per benchmark, including the LSTM `[40]`/`[256]`
//!   divisibility quirks) plus the paper's reported numbers, so reports
//!   can print paper-vs-model side by side.
//! * [`explore`] — the design-space explorer on top of all of the above:
//!   sweep reuse × precision × strategy × clock × RNN mode over the model
//!   zoo, evaluate every candidate through [`design::HlsDesign`], prune
//!   to the Pareto front on (latency, II, DSP/LUT/FF/BRAM, accuracy),
//!   answer budget queries (`cheapest_within`), join measured AUC from
//!   `report::accuracy` for checkpoint models, and emit each front row
//!   as a named backend candidate for the tiered serving layer.
//!
//! Calibration: the model's free constants are fixed against the anchor
//! points the paper states (top-tagging static II 315/314 ≈ seq × 16 at
//! 200 MHz; latency ∝ reuse with slope 1 cycle/step per reuse unit;
//! QuickDraw latency table reproducing to <5%; DSP counts exactly
//! `mults/R`).  See `EXPERIMENTS.md` for the measured deltas.

pub mod design;
pub mod device;
pub mod explore;
pub mod latency;
pub mod paper;
pub mod resource;

pub use design::{DesignError, HlsDesign, SynthesisReport};
pub use device::Device;
pub use latency::{DesignTiming, Strategy};
pub use resource::ResourceEstimate;

use crate::fixed::FixedSpec;

/// Reuse factors for the two RNN matrix multiplications (the paper's
/// `R = (X, Y)`: `kernel` for `W·x`, `recurrent` for `U·h`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ReuseFactor {
    pub kernel: usize,
    pub recurrent: usize,
}

impl ReuseFactor {
    pub fn new(kernel: usize, recurrent: usize) -> Self {
        assert!(kernel >= 1 && recurrent >= 1, "reuse factors must be >= 1");
        Self { kernel, recurrent }
    }

    /// Fully parallel (one mult per DSP) — what latency strategy uses.
    pub fn fully_parallel() -> Self {
        Self::new(1, 1)
    }

    /// The larger of the two factors (bounds the cell II).
    pub fn max_factor(&self) -> usize {
        self.kernel.max(self.recurrent)
    }

    /// Paper notation, e.g. `R = (12, 10)`.
    pub fn label(&self) -> String {
        format!("({}, {})", self.kernel, self.recurrent)
    }
}

/// The paper's RNN-specific tuning knob (§3, Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RnnMode {
    /// One RNN block processes every sequence step; state lives inside
    /// the block; II == RNN latency (minimum resources).
    Static,
    /// One RNN block *per step*, state passed block to block; resources
    /// × seq_len, II reduced to the II of a single block.
    NonStatic,
}

impl RnnMode {
    pub fn label(&self) -> &'static str {
        match self {
            RnnMode::Static => "static",
            RnnMode::NonStatic => "non-static",
        }
    }
}

/// Complete configuration of one synthesis run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HlsConfig {
    /// Fixed-point type for all layers (§5.1 fixes one type everywhere).
    pub spec: FixedSpec,
    pub reuse: ReuseFactor,
    pub strategy: Strategy,
    pub mode: RnnMode,
    /// Synthesis clock (paper: 200 MHz).
    pub clock_mhz: f64,
}

impl HlsConfig {
    /// The paper's defaults: 200 MHz, static mode, resource strategy.
    pub fn paper_default(spec: FixedSpec, reuse: ReuseFactor) -> Self {
        Self {
            spec,
            reuse,
            strategy: Strategy::Resource,
            mode: RnnMode::Static,
            clock_mhz: 200.0,
        }
    }

    /// Cycle time in µs.
    pub fn cycle_us(&self) -> f64 {
        1.0 / self.clock_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_label_matches_paper() {
        assert_eq!(ReuseFactor::new(12, 10).label(), "(12, 10)");
        assert_eq!(ReuseFactor::new(60, 40).max_factor(), 60);
    }

    #[test]
    #[should_panic]
    fn zero_reuse_rejected() {
        ReuseFactor::new(0, 1);
    }

    #[test]
    fn cycle_time_at_200mhz() {
        let cfg = HlsConfig::paper_default(
            FixedSpec::new(16, 6),
            ReuseFactor::new(6, 5),
        );
        assert!((cfg.cycle_us() - 0.005).abs() < 1e-12);
    }
}
