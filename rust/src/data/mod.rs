//! Datasets and metrics.
//!
//! * [`dataset`] — reader for the frozen binary test sets written by
//!   `python/compile/data.py` (`artifacts/data/*_test.bin`); these drive
//!   the Fig. 2 quantization scan bit-reproducibly.
//! * [`generators`] — rust-side synthetic generators mirroring the python
//!   algorithms (top-tagging jets, flavor-tagging tracks, QuickDraw
//!   strokes); these feed the live event source of the serving demo.
//! * [`metrics`] — ROC AUC (binary via the Mann–Whitney rank statistic,
//!   multi-class one-vs-rest), matching `python/compile/train.py`.

pub mod dataset;
pub mod generators;
pub mod metrics;

pub use dataset::Dataset;
pub use metrics::{binary_auc, mean_auc, multiclass_auc};
