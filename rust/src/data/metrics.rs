//! ROC AUC metrics, matching `python/compile/train.py` (midrank ties).

/// Binary ROC AUC via the Mann–Whitney U statistic with midrank tie
/// handling.  Degenerate label sets return 0.5.
pub fn binary_auc(scores: &[f32], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&l| l).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // Sort indices by score; assign midranks over tie groups.
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).expect("finite scores")
    });
    let mut ranks = vec![0.0f64; scores.len()];
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let midrank = (i + 1 + j + 1) as f64 / 2.0;
        for &idx in &order[i..=j] {
            ranks[idx] = midrank;
        }
        i = j + 1;
    }
    let r_pos: f64 = ranks
        .iter()
        .zip(labels)
        .filter(|(_, &l)| l)
        .map(|(&r, _)| r)
        .sum();
    let u = r_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// One-vs-rest AUC per class; `probs` is row-major `[n][n_classes]`.
pub fn multiclass_auc(
    probs: &[Vec<f32>],
    labels: &[u32],
    n_classes: usize,
) -> Vec<f64> {
    (0..n_classes)
        .map(|k| {
            let scores: Vec<f32> = probs.iter().map(|p| p[k]).collect();
            let is_k: Vec<bool> = labels.iter().map(|&l| l as usize == k).collect();
            binary_auc(&scores, &is_k)
        })
        .collect()
}

/// The scalar quality figure used for Fig. 2: binary AUC for the
/// top-tagging task, macro-averaged one-vs-rest AUC otherwise.
pub fn mean_auc(probs: &[Vec<f32>], labels: &[u32], n_classes: usize) -> f64 {
    if n_classes == 1 {
        let scores: Vec<f32> = probs.iter().map(|p| p[0]).collect();
        let is_pos: Vec<bool> = labels.iter().map(|&l| l == 1).collect();
        binary_auc(&scores, &is_pos)
    } else {
        let per = multiclass_auc(probs, labels, n_classes);
        per.iter().sum::<f64>() / n_classes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_and_inverted() {
        let scores = [0.9, 0.8, 0.7, 0.2, 0.1, 0.0];
        let labels = [true, true, true, false, false, false];
        assert_eq!(binary_auc(&scores, &labels), 1.0);
        let inv: Vec<f32> = scores.iter().map(|s| 1.0 - s).collect();
        assert_eq!(binary_auc(&inv, &labels), 0.0);
    }

    #[test]
    fn chance_for_constant_scores() {
        let scores = [0.5f32; 6];
        let labels = [true, false, true, false, true, false];
        assert!((binary_auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn midrank_ties_match_python() {
        // Mirrors python/tests/test_train.py::test_binary_auc_with_ties.
        let scores = [0.5, 0.5, 0.5, 0.1];
        let labels = [true, false, true, false];
        assert!((binary_auc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn degenerate_labels_are_half() {
        assert_eq!(binary_auc(&[0.1, 0.9], &[true, true]), 0.5);
        assert_eq!(binary_auc(&[0.1, 0.9], &[false, false]), 0.5);
    }

    #[test]
    fn multiclass_reduces_to_binary_per_class() {
        let probs = vec![
            vec![0.7, 0.2, 0.1],
            vec![0.1, 0.8, 0.1],
            vec![0.2, 0.2, 0.6],
            vec![0.6, 0.3, 0.1],
        ];
        let labels = [0u32, 1, 2, 1];
        let per = multiclass_auc(&probs, &labels, 3);
        assert_eq!(per.len(), 3);
        // class 0: sample 0 is positive with the highest class-0 prob
        // except sample 3 ties the ordering: check against manual calc.
        let s0: Vec<f32> = probs.iter().map(|p| p[0]).collect();
        let l0 = [true, false, false, false];
        assert_eq!(per[0], binary_auc(&s0, &l0));
    }

    #[test]
    fn mean_auc_binary_uses_label_one() {
        let probs = vec![vec![0.9], vec![0.1]];
        let labels = [1u32, 0];
        assert_eq!(mean_auc(&probs, &labels, 1), 1.0);
    }
}
