//! ROC AUC metrics, matching `python/compile/train.py` (midrank ties).

/// Binary ROC AUC via the Mann–Whitney U statistic with midrank tie
/// handling.  Degenerate label sets return 0.5.
///
/// NaN scores carry no ranking information (a degenerate softmax or a
/// saturating fixed-point path can emit them): they are counted and
/// excluded rather than panicking, so one bad sample cannot take down a
/// whole accuracy sweep.  ±Inf scores are finite ranks (`total_cmp`
/// order).  Callers that treat NaN as a hard error run
/// [`require_finite`] up front.
pub fn binary_auc(scores: &[f32], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let mut order: Vec<usize> =
        (0..scores.len()).filter(|&i| !scores[i].is_nan()).collect();
    let n_pos = order.iter().filter(|&&i| labels[i]).count();
    let n_neg = order.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // Sort kept indices by score; assign midranks over tie groups.
    order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    let mut ranks = vec![0.0f64; scores.len()];
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let midrank = (i + 1 + j + 1) as f64 / 2.0;
        for &idx in &order[i..=j] {
            ranks[idx] = midrank;
        }
        i = j + 1;
    }
    let r_pos: f64 =
        order.iter().filter(|&&i| labels[i]).map(|&i| ranks[i]).sum();
    let u = r_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Reject non-finite probabilities up front, naming the first offending
/// sample and class — for callers that want NaN/±Inf to be a typed
/// error instead of [`binary_auc`]'s count-and-exclude policy.
pub fn require_finite(probs: &[Vec<f32>]) -> anyhow::Result<()> {
    for (i, row) in probs.iter().enumerate() {
        for (k, &p) in row.iter().enumerate() {
            anyhow::ensure!(
                p.is_finite(),
                "non-finite probability {p} at sample {i}, class {k}"
            );
        }
    }
    Ok(())
}

/// One-vs-rest AUC per class; `probs` is row-major `[n][n_classes]`.
pub fn multiclass_auc(
    probs: &[Vec<f32>],
    labels: &[u32],
    n_classes: usize,
) -> Vec<f64> {
    (0..n_classes)
        .map(|k| {
            let scores: Vec<f32> = probs.iter().map(|p| p[k]).collect();
            let is_k: Vec<bool> = labels.iter().map(|&l| l as usize == k).collect();
            binary_auc(&scores, &is_k)
        })
        .collect()
}

/// The scalar quality figure used for Fig. 2: binary AUC for the
/// top-tagging task, macro-averaged one-vs-rest AUC otherwise.
pub fn mean_auc(probs: &[Vec<f32>], labels: &[u32], n_classes: usize) -> f64 {
    if n_classes == 1 {
        let scores: Vec<f32> = probs.iter().map(|p| p[0]).collect();
        let is_pos: Vec<bool> = labels.iter().map(|&l| l == 1).collect();
        binary_auc(&scores, &is_pos)
    } else {
        let per = multiclass_auc(probs, labels, n_classes);
        per.iter().sum::<f64>() / n_classes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_and_inverted() {
        let scores = [0.9, 0.8, 0.7, 0.2, 0.1, 0.0];
        let labels = [true, true, true, false, false, false];
        assert_eq!(binary_auc(&scores, &labels), 1.0);
        let inv: Vec<f32> = scores.iter().map(|s| 1.0 - s).collect();
        assert_eq!(binary_auc(&inv, &labels), 0.0);
    }

    #[test]
    fn chance_for_constant_scores() {
        let scores = [0.5f32; 6];
        let labels = [true, false, true, false, true, false];
        assert!((binary_auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn midrank_ties_match_python() {
        // Mirrors python/tests/test_train.py::test_binary_auc_with_ties.
        let scores = [0.5, 0.5, 0.5, 0.1];
        let labels = [true, false, true, false];
        assert!((binary_auc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn degenerate_labels_are_half() {
        assert_eq!(binary_auc(&[0.1, 0.9], &[true, true]), 0.5);
        assert_eq!(binary_auc(&[0.1, 0.9], &[false, false]), 0.5);
    }

    #[test]
    fn nan_scores_are_excluded_not_fatal() {
        // A perfect separation plus one NaN: the NaN sample drops out
        // and the remaining ranking is still perfect.
        let scores = [0.9, 0.8, f32::NAN, 0.2, 0.1];
        let labels = [true, true, true, false, false];
        assert_eq!(binary_auc(&scores, &labels), 1.0);
        // NaN on the negative side likewise.
        let scores = [0.9, 0.8, f32::NAN, 0.1];
        let labels = [true, true, false, false];
        assert_eq!(binary_auc(&scores, &labels), 1.0);
    }

    #[test]
    fn all_nan_scores_are_chance() {
        let scores = [f32::NAN, f32::NAN, f32::NAN];
        let labels = [true, false, true];
        assert_eq!(binary_auc(&scores, &labels), 0.5);
    }

    #[test]
    fn infinities_rank_without_panicking() {
        // +Inf outranks everything, -Inf ranks below everything.
        let scores = [f32::INFINITY, 0.5, f32::NEG_INFINITY, 0.2];
        let labels = [true, true, false, false];
        assert_eq!(binary_auc(&scores, &labels), 1.0);
        let labels_inv = [false, false, true, true];
        assert_eq!(binary_auc(&scores, &labels_inv), 0.0);
    }

    #[test]
    fn require_finite_names_the_offender() {
        let good = vec![vec![0.2, 0.8], vec![0.9, 0.1]];
        assert!(require_finite(&good).is_ok());
        let bad = vec![vec![0.2, 0.8], vec![f32::NAN, 0.1]];
        let err = require_finite(&bad).unwrap_err().to_string();
        assert!(err.contains("sample 1"), "{err}");
        assert!(err.contains("class 0"), "{err}");
        let inf = vec![vec![f32::INFINITY]];
        assert!(require_finite(&inf).is_err());
    }

    #[test]
    fn multiclass_reduces_to_binary_per_class() {
        let probs = vec![
            vec![0.7, 0.2, 0.1],
            vec![0.1, 0.8, 0.1],
            vec![0.2, 0.2, 0.6],
            vec![0.6, 0.3, 0.1],
        ];
        let labels = [0u32, 1, 2, 1];
        let per = multiclass_auc(&probs, &labels, 3);
        assert_eq!(per.len(), 3);
        // class 0: sample 0 is positive with the highest class-0 prob
        // except sample 3 ties the ordering: check against manual calc.
        let s0: Vec<f32> = probs.iter().map(|p| p[0]).collect();
        let l0 = [true, false, false, false];
        assert_eq!(per[0], binary_auc(&s0, &l0));
    }

    #[test]
    fn mean_auc_binary_uses_label_one() {
        let probs = vec![vec![0.9], vec![0.1]];
        let labels = [1u32, 0];
        assert_eq!(mean_auc(&probs, &labels, 1), 1.0);
    }
}
