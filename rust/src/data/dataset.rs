//! Binary test-set container (format documented in
//! `python/compile/data.py`): magic `RNNDAT01`, four u32 LE header words
//! (n, seq, feat, classes), f32 LE data, u32 LE labels.

use std::path::Path;

const MAGIC: &[u8; 8] = b"RNNDAT01";

/// A loaded evaluation set.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub seq_len: usize,
    pub n_feat: usize,
    /// 1 => binary task (sigmoid output), else the class count.
    pub n_classes: usize,
    /// Row-major `[sample][step][feature]`.
    data: Vec<f32>,
    labels: Vec<u32>,
}

impl Dataset {
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(bytes.len() >= 24, "dataset too short");
        anyhow::ensure!(
            &bytes[..8] == MAGIC,
            "bad magic {:?} (want RNNDAT01)",
            &bytes[..8]
        );
        let word = |i: usize| -> usize {
            u32::from_le_bytes(bytes[8 + 4 * i..12 + 4 * i].try_into().unwrap())
                as usize
        };
        let (n, seq_len, n_feat, n_classes) = (word(0), word(1), word(2), word(3));
        // Header words are untrusted input: reject zero dims and size
        // arithmetic that overflows usize before the length check (and
        // before any allocation sized from them).
        anyhow::ensure!(
            n > 0 && seq_len > 0 && n_feat > 0 && n_classes > 0,
            "dataset header has a zero dimension \
             (n={n}, seq={seq_len}, feat={n_feat}, classes={n_classes})"
        );
        let overflow = || {
            anyhow::anyhow!(
                "dataset header overflows \
                 (n={n}, seq={seq_len}, feat={n_feat})"
            )
        };
        let elems = n
            .checked_mul(seq_len)
            .and_then(|v| v.checked_mul(n_feat))
            .ok_or_else(overflow)?;
        let data_bytes = elems.checked_mul(4).ok_or_else(overflow)?;
        let labels_bytes = n.checked_mul(4).ok_or_else(overflow)?;
        let want = 24usize
            .checked_add(data_bytes)
            .and_then(|v| v.checked_add(labels_bytes))
            .ok_or_else(overflow)?;
        anyhow::ensure!(
            bytes.len() == want,
            "dataset length {} != expected {want} (n={n}, seq={seq_len}, feat={n_feat})",
            bytes.len()
        );
        let mut data = Vec::with_capacity(elems);
        for chunk in bytes[24..24 + data_bytes].chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        let mut labels = Vec::with_capacity(n);
        for chunk in bytes[24 + data_bytes..].chunks_exact(4) {
            labels.push(u32::from_le_bytes(chunk.try_into().unwrap()));
        }
        anyhow::ensure!(
            data.iter().all(|v| v.is_finite()),
            "dataset contains non-finite features"
        );
        Ok(Self {
            n,
            seq_len,
            n_feat,
            n_classes,
            data,
            labels,
        })
    }

    /// One sample as a flat `[seq_len * n_feat]` slice.
    #[inline]
    pub fn sample(&self, i: usize) -> &[f32] {
        let stride = self.seq_len * self.n_feat;
        &self.data[i * stride..(i + 1) * stride]
    }

    #[inline]
    pub fn label(&self, i: usize) -> u32 {
        self.labels[i]
    }

    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// Restrict to the first `n` samples (cheap evaluation subsets).
    pub fn truncated(&self, n: usize) -> Dataset {
        let n = n.min(self.n);
        let stride = self.seq_len * self.n_feat;
        Dataset {
            n,
            seq_len: self.seq_len,
            n_feat: self.n_feat,
            n_classes: self.n_classes,
            data: self.data[..n * stride].to_vec(),
            labels: self.labels[..n].to_vec(),
        }
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    /// Serialize a dataset in the container format (mirror of the python
    /// writer, for tests).
    pub fn encode(
        seq: usize,
        feat: usize,
        classes: usize,
        samples: &[(Vec<f32>, u32)],
    ) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"RNNDAT01");
        for v in [samples.len() as u32, seq as u32, feat as u32, classes as u32] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for (x, _) in samples {
            assert_eq!(x.len(), seq * feat);
            for f in x {
                out.extend_from_slice(&f.to_le_bytes());
            }
        }
        for (_, y) in samples {
            out.extend_from_slice(&y.to_le_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::encode;
    use super::*;

    #[test]
    fn roundtrip() {
        let samples = vec![
            (vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 1u32),
            (vec![-1.0, -2.0, -3.0, -4.0, -5.0, -6.0], 0u32),
        ];
        let bytes = encode(3, 2, 1, &samples);
        let ds = Dataset::from_bytes(&bytes).unwrap();
        assert_eq!((ds.n, ds.seq_len, ds.n_feat, ds.n_classes), (2, 3, 2, 1));
        assert_eq!(ds.sample(0), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(ds.label(1), 0);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = encode(1, 1, 1, &[(vec![0.0], 0)]);
        bytes[0] = b'X';
        assert!(Dataset::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let bytes = encode(3, 2, 1, &[(vec![0.0; 6], 0)]);
        assert!(Dataset::from_bytes(&bytes[..bytes.len() - 2]).is_err());
    }

    /// Patch one u32 header word (0 = n, 1 = seq, 2 = feat, 3 = classes).
    fn poke_header(bytes: &mut [u8], word: usize, value: u32) {
        bytes[8 + 4 * word..12 + 4 * word].copy_from_slice(&value.to_le_bytes());
    }

    #[test]
    fn rejects_overflowing_header_dims() {
        // Each of n/seq/feat at u32::MAX (and all three together) must be
        // a clean error — the unchecked product used to overflow usize on
        // 32-bit and produce a bogus length check.
        for word in 0..3 {
            let mut bytes = encode(3, 2, 1, &[(vec![0.0; 6], 0)]);
            poke_header(&mut bytes, word, u32::MAX);
            let err = Dataset::from_bytes(&bytes).unwrap_err().to_string();
            assert!(
                err.contains("!=") || err.contains("overflows"),
                "word {word}: {err}"
            );
        }
        let mut bytes = encode(3, 2, 1, &[(vec![0.0; 6], 0)]);
        for word in 0..3 {
            poke_header(&mut bytes, word, u32::MAX);
        }
        // (2^32-1)^3 * 4 overflows even 64-bit usize.
        let err = Dataset::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");
    }

    #[test]
    fn rejects_zero_dims() {
        for word in 0..4 {
            let mut bytes = encode(3, 2, 1, &[(vec![0.0; 6], 0)]);
            poke_header(&mut bytes, word, 0);
            let err = Dataset::from_bytes(&bytes).unwrap_err().to_string();
            assert!(err.contains("zero dimension"), "word {word}: {err}");
        }
    }

    #[test]
    fn rejects_huge_n_with_short_payload() {
        // A header claiming a billion samples over a 40-byte body must
        // fail the length check without allocating gigabytes first.
        let mut bytes = encode(3, 2, 1, &[(vec![0.0; 6], 0)]);
        poke_header(&mut bytes, 0, 1_000_000_000);
        assert!(Dataset::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_nan_features() {
        let bytes = encode(1, 1, 1, &[(vec![f32::NAN], 0)]);
        assert!(Dataset::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_keeps_prefix() {
        let samples = vec![
            (vec![1.0], 0u32),
            (vec![2.0], 1u32),
            (vec![3.0], 2u32),
        ];
        let ds = Dataset::from_bytes(&encode(1, 1, 3, &samples)).unwrap();
        let t = ds.truncated(2);
        assert_eq!(t.n, 2);
        assert_eq!(t.sample(1), &[2.0]);
        assert_eq!(ds.truncated(99).n, 3);
    }
}
