//! Rust-side synthetic event generators, mirroring
//! `python/compile/data.py` algorithm-for-algorithm.
//!
//! These produce the *live* workload for the serving coordinator (the
//! paper's trigger scenario: events arrive at up to 40 MHz and each must
//! be classified within microseconds).  Training/evaluation sets come
//! from the frozen python-generated artifacts instead, so Fig. 2 numbers
//! are bit-reproducible; the rust generators only need to match the
//! python ones *distributionally*, which the cross-language tests check
//! (feature ranges, class separations).

use crate::util::rng::Rng;

/// One generated event: a flat `[seq_len * n_feat]` feature row + label.
#[derive(Debug, Clone)]
pub struct Event {
    pub features: Vec<f32>,
    pub label: u32,
}

/// A benchmark-specific event generator.
pub trait Generator: Send {
    fn name(&self) -> &'static str;
    fn seq_len(&self) -> usize;
    fn n_feat(&self) -> usize;
    fn n_classes(&self) -> usize;
    fn generate(&mut self) -> Event;
}

pub fn for_benchmark(name: &str, seed: u64) -> anyhow::Result<Box<dyn Generator>> {
    match name {
        "top" => Ok(Box::new(TopTagging::new(seed))),
        "flavor" => Ok(Box::new(FlavorTagging::new(seed))),
        "quickdraw" => Ok(Box::new(QuickDraw::new(seed))),
        other => anyhow::bail!("no generator for benchmark {other:?}"),
    }
}

// --------------------------------------------------------------------------
// Top tagging: 1-prong light jets vs 3-prong top jets.
// Features: [log pT, eta_rel, phi_rel, log E, dR, pid]
// --------------------------------------------------------------------------

pub struct TopTagging {
    rng: Rng,
}

impl TopTagging {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }
}

impl Generator for TopTagging {
    fn name(&self) -> &'static str {
        "top"
    }
    fn seq_len(&self) -> usize {
        20
    }
    fn n_feat(&self) -> usize {
        6
    }
    fn n_classes(&self) -> usize {
        1
    }

    fn generate(&mut self) -> Event {
        let (seq_len, n_feat) = (self.seq_len(), self.n_feat());
        let rng = &mut self.rng;
        let is_top = rng.uniform() < 0.5;
        let n_prong = if is_top {
            3
        } else if rng.uniform() < 0.8 {
            1
        } else {
            2
        };
        let spread = if is_top { 0.35 } else { 0.12 };
        let axes: Vec<(f64, f64)> = (0..n_prong)
            .map(|_| (rng.normal(0.0, spread), rng.normal(0.0, spread)))
            .collect();
        let frac = rng.dirichlet(n_prong, 3.0);
        let jet_pt = rng.normal(1000.0, 10.0);

        let n_part = 12 + rng.below(seq_len - 12 + 1);
        let mut parts: Vec<(f64, f64, f64, f64)> = (0..n_part)
            .map(|_| {
                let prong = rng.choice_weighted(&frac);
                let pt = frac[prong] * jet_pt * rng.exponential(0.22);
                let width = if is_top { 0.05 } else { 0.08 };
                let eta = axes[prong].0 + rng.normal(0.0, width);
                let phi = axes[prong].1 + rng.normal(0.0, width);
                let pid = (rng.below(5) as f64) - 2.0;
                (pt, eta, phi, pid)
            })
            .collect();
        parts.sort_by(|a, b| b.0.partial_cmp(&a.0).expect("finite pT"));

        let mut features = vec![0.0f32; seq_len * n_feat];
        for (i, &(pt, eta, phi, pid)) in parts.iter().enumerate() {
            let energy = pt * eta.cosh();
            let dr = (eta * eta + phi * phi).sqrt();
            let row = &mut features[i * 6..(i + 1) * 6];
            row[0] = (pt.ln_1p() / 7.0) as f32;
            row[1] = eta as f32;
            row[2] = phi as f32;
            row[3] = (energy.ln_1p() / 7.0) as f32;
            row[4] = dr as f32;
            row[5] = (pid / 2.0) as f32;
        }
        Event {
            features,
            label: u32::from(is_top),
        }
    }
}

// --------------------------------------------------------------------------
// Flavor tagging: displaced-track toy, labels 0=light, 1=c, 2=b.
// Features: [pt_rel, dR, d0, dz, S(d0), S(dz)]
// --------------------------------------------------------------------------

pub struct FlavorTagging {
    rng: Rng,
}

impl FlavorTagging {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }
}

impl Generator for FlavorTagging {
    fn name(&self) -> &'static str {
        "flavor"
    }
    fn seq_len(&self) -> usize {
        15
    }
    fn n_feat(&self) -> usize {
        6
    }
    fn n_classes(&self) -> usize {
        3
    }

    fn generate(&mut self) -> Event {
        let (seq_len, n_feat) = (self.seq_len(), self.n_feat());
        let rng = &mut self.rng;
        let label = rng.below(3) as u32;
        let (mult, d0_scale, _sig) = match label {
            0 => (0.25, 0.010, 1.0),
            1 => (1.8, 0.025, 2.5),
            _ => (3.5, 0.045, 5.0),
        };
        let n_trk = 6 + rng.below(seq_len - 6 + 1);
        let n_disp = rng.poisson(mult).min(n_trk);

        struct Track {
            pt_rel: f64,
            dr: f64,
            d0: f64,
            dz: f64,
            s_d0: f64,
            s_dz: f64,
        }
        let mut tracks: Vec<Track> = (0..n_trk)
            .map(|t| {
                let mut d0 = rng.normal(0.0, 0.008);
                let mut dz = rng.normal(0.0, 0.015);
                if t < n_disp {
                    let sign = if rng.uniform() < 0.1 { -1.0 } else { 1.0 };
                    d0 = sign * rng.exponential(d0_scale);
                    dz += rng.normal(0.0, d0_scale);
                }
                let sigma_d0 = rng.range(0.006, 0.014);
                let sigma_dz = rng.range(0.010, 0.025);
                let s_d0 = d0 / sigma_d0 + rng.normal(0.0, 0.3);
                let s_dz = dz / sigma_dz + rng.normal(0.0, 0.3);
                // beta(1.5, 6) approximated by a clipped gamma ratio.
                let a = rng.exponential(1.5);
                let b = rng.exponential(6.0);
                let pt_rel = (a / (a + b + 1e-9)).min(0.999);
                let dr = rng.exponential(0.12).min(0.5);
                Track {
                    pt_rel,
                    dr,
                    d0,
                    dz,
                    s_d0,
                    s_dz,
                }
            })
            .collect();
        tracks.sort_by(|a, b| {
            b.s_d0
                .abs()
                .partial_cmp(&a.s_d0.abs())
                .expect("finite significance")
        });

        let mut features = vec![0.0f32; seq_len * n_feat];
        for (i, t) in tracks.iter().enumerate() {
            let row = &mut features[i * 6..(i + 1) * 6];
            row[0] = t.pt_rel as f32;
            row[1] = t.dr as f32;
            row[2] = ((t.d0 * 10.0).clamp(-4.0, 4.0)) as f32;
            row[3] = ((t.dz * 10.0).clamp(-4.0, 4.0)) as f32;
            row[4] = ((t.s_d0 / 4.0).clamp(-6.0, 6.0)) as f32;
            row[5] = ((t.s_dz / 4.0).clamp(-6.0, 6.0)) as f32;
        }
        Event { features, label }
    }
}

// --------------------------------------------------------------------------
// QuickDraw: parametric stroke families. Features: [x, y, t]
// --------------------------------------------------------------------------

pub struct QuickDraw {
    rng: Rng,
}

impl QuickDraw {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }

    fn curve(class: u32, s: f64) -> (f64, f64) {
        use std::f64::consts::PI;
        let two_pi = 2.0 * PI;
        match class {
            0 => {
                // "ant": three body segments as successive circles
                let seg = (s * 3.0).floor().min(2.0);
                let phase = (s * 3.0 - seg) * two_pi;
                let cx = (seg - 1.0) * 0.9;
                let r = 0.35 + if seg == 1.0 { 0.1 } else { 0.0 };
                (cx + r * phase.cos(), r * phase.sin())
            }
            1 => {
                // "butterfly": four-petal rose
                let theta = s * two_pi;
                let r = (2.0 * theta).cos().abs() + 0.15;
                (r * theta.cos(), r * theta.sin())
            }
            2 => {
                // "bee": ellipse + zigzag stripes
                let theta = s * two_pi;
                let x = 1.2 * theta.cos();
                let stripes = if s > 0.5 {
                    0.25 * (theta * 8.0).sin().signum()
                } else {
                    0.0
                };
                (x, 0.6 * theta.sin() + stripes)
            }
            3 => {
                // "mosquito": radial legs
                let n_ray = 6.0;
                let ray = (s * n_ray).floor().min(n_ray - 1.0);
                let along = s * n_ray - ray;
                let dist = 0.2 + 1.3 * (1.0 - (2.0 * along - 1.0).abs());
                let ang = ray / n_ray * two_pi + 0.3;
                (dist * ang.cos(), dist * ang.sin())
            }
            _ => {
                // "snail": Archimedean spiral
                let theta = s * 3.0 * two_pi;
                let r = 0.08 + 0.10 * theta;
                (r * theta.cos(), r * theta.sin())
            }
        }
    }
}

impl Generator for QuickDraw {
    fn name(&self) -> &'static str {
        "quickdraw"
    }
    fn seq_len(&self) -> usize {
        100
    }
    fn n_feat(&self) -> usize {
        3
    }
    fn n_classes(&self) -> usize {
        5
    }

    fn generate(&mut self) -> Event {
        let n = self.seq_len();
        let rng = &mut self.rng;
        let label = rng.below(5) as u32;
        let ang = rng.range(0.0, 2.0 * std::f64::consts::PI);
        let (ca, sa) = (ang.cos(), ang.sin());
        let (sx, sy) = (rng.range(0.7, 1.3), rng.range(0.7, 1.3));
        let (ox, oy) = (rng.normal(0.0, 0.15), rng.normal(0.0, 0.15));

        let mut pts = Vec::with_capacity(n);
        for i in 0..n {
            let s = i as f64 / (n - 1) as f64;
            let (mut x, mut y) = Self::curve(label, s);
            x *= sx;
            y *= sy;
            let (rx, ry) = (ca * x - sa * y, sa * x + ca * y);
            pts.push((
                rx + ox + rng.normal(0.0, 0.04),
                ry + oy + rng.normal(0.0, 0.04),
            ));
        }
        // Raw coordinate scale (mirrors python: the real QuickDraw data
        // is on a ~0-255 canvas; this is what forces >= 10 integer bits
        // in Fig. 2c).
        for p in pts.iter_mut() {
            p.0 *= 200.0 / 1.6;
            p.1 *= 200.0 / 1.6;
        }
        // Timestamp: noisy cumulative arc length scaled to the game's
        // 15-second window.
        let mut t = vec![0.0f64; n];
        for i in 1..n {
            let (dx, dy) = (pts[i].0 - pts[i - 1].0, pts[i].1 - pts[i - 1].1);
            let seg = (dx * dx + dy * dy).sqrt() * rng.range(0.7, 1.3);
            t[i] = t[i - 1] + seg;
        }
        let total = t[n - 1].max(1e-6);

        let mut features = vec![0.0f32; n * 3];
        for i in 0..n {
            features[i * 3] = pts[i].0 as f32;
            features[i * 3 + 1] = pts[i].1 as f32;
            features[i * 3 + 2] = (15.0 * t[i] / total) as f32;
        }
        Event { features, label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_many(
        gen: &mut dyn Generator,
        n: usize,
    ) -> (Vec<Vec<f32>>, Vec<u32>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let e = gen.generate();
            assert_eq!(e.features.len(), gen.seq_len() * gen.n_feat());
            xs.push(e.features);
            ys.push(e.label);
        }
        (xs, ys)
    }

    #[test]
    fn all_generators_produce_bounded_finite_features() {
        for name in ["top", "flavor", "quickdraw"] {
            let mut gen = for_benchmark(name, 11).unwrap();
            let (xs, _ys) = sample_many(gen.as_mut(), 200);
            // quickdraw keeps the raw ~0-255 coordinate scale (needs
            // >= 10 integer bits, Fig. 2c); the others are O(1).
            let bound = if name == "quickdraw" { 512.0 } else { 32.0 };
            for x in &xs {
                for &v in x {
                    assert!(v.is_finite(), "{name}");
                    assert!(v.abs() < bound, "{name}: feature {v} too large");
                }
            }
        }
    }

    #[test]
    fn labels_cover_all_classes() {
        for (name, n_labels) in [("top", 2usize), ("flavor", 3), ("quickdraw", 5)] {
            let mut gen = for_benchmark(name, 13).unwrap();
            let (_xs, ys) = sample_many(gen.as_mut(), 400);
            let mut seen = vec![false; n_labels];
            for &y in &ys {
                seen[y as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "{name}: labels {seen:?}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = TopTagging::new(5);
        let mut b = TopTagging::new(5);
        let ea = a.generate();
        let eb = b.generate();
        assert_eq!(ea.features, eb.features);
        assert_eq!(ea.label, eb.label);
    }

    /// Same separation property the python test asserts: top jets have
    /// wider dR spread than light jets.
    #[test]
    fn top_prong_structure_separates() {
        let mut gen = TopTagging::new(17);
        let (xs, ys) = sample_many(&mut gen, 800);
        let mut sig = (0.0f64, 0usize);
        let mut bkg = (0.0f64, 0usize);
        for (x, &y) in xs.iter().zip(&ys) {
            let mut dr_sum = 0.0f64;
            let mut count = 0usize;
            for p in 0..20 {
                if x[p * 6] > 0.0 {
                    dr_sum += x[p * 6 + 4] as f64;
                    count += 1;
                }
            }
            let spread = dr_sum / count.max(1) as f64;
            if y == 1 {
                sig = (sig.0 + spread, sig.1 + 1);
            } else {
                bkg = (bkg.0 + spread, bkg.1 + 1);
            }
        }
        let (ms, mb) = (sig.0 / sig.1 as f64, bkg.0 / bkg.1 as f64);
        assert!(ms > mb * 1.3, "top {ms:.3} vs light {mb:.3}");
    }

    /// b > c > light in leading-track |S(d0)|, as in the python test.
    #[test]
    fn flavor_displacement_orders_classes() {
        let mut gen = FlavorTagging::new(19);
        let (xs, ys) = sample_many(&mut gen, 1200);
        let mut sums = [0.0f64; 3];
        let mut counts = [0usize; 3];
        for (x, &y) in xs.iter().zip(&ys) {
            sums[y as usize] += (x[4] as f64).abs();
            counts[y as usize] += 1;
        }
        let means: Vec<f64> = (0..3).map(|k| sums[k] / counts[k] as f64).collect();
        assert!(
            means[2] > means[1] && means[1] > means[0],
            "means {means:?}"
        );
    }

    #[test]
    fn quickdraw_timestamps_monotone() {
        let mut gen = QuickDraw::new(23);
        for _ in 0..50 {
            let e = gen.generate();
            let mut prev = -1e-4f32;
            for i in 0..100 {
                let t = e.features[i * 3 + 2];
                assert!(t >= prev);
                prev = t;
            }
            assert!((prev - 15.0).abs() < 1e-3);
        }
    }
}
