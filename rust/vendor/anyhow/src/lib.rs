//! In-tree minimal substitute for the `anyhow` crate.
//!
//! This build environment resolves no registry crates, so the slice of
//! `anyhow` the codebase actually uses is implemented here:
//!
//! * [`Error`] — an opaque, message-carrying error (`Send + Sync`).
//! * [`Result`] — `Result<T, Error>` alias with a defaulted error type.
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — the formatting macros.
//! * A blanket `From<E: std::error::Error>` so `?` converts any std
//!   error (io, parse, utf8, the stub `xla::Error`, …).
//!
//! Deliberately *not* implemented (unused in this tree): context chains,
//! downcasting, backtraces.  Like the real crate, [`Error`] does not
//! implement `std::error::Error` itself — that is what makes the blanket
//! `From` coherent.

use std::fmt;

/// An opaque error carrying a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (alternate) renders the same: there is no cause chain.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        Error::msg(&err)
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (inline captures work).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[allow(unused_imports)]
    use super::*;

    fn parse_num(s: &str) -> crate::Result<u32> {
        let n: u32 = s.parse()?; // From<ParseIntError>
        crate::ensure!(n < 100, "too big: {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse_num("42").unwrap(), 42);
        assert!(parse_num("abc").is_err());
        assert_eq!(parse_num("200").unwrap_err().to_string(), "too big: 200");
    }

    #[test]
    fn macros_format() {
        let e = crate::anyhow!("x = {}, y = {y}", 1, y = 2);
        assert_eq!(e.to_string(), "x = 1, y = 2");
        let e2 = crate::anyhow!("plain");
        assert_eq!(format!("{e2}"), "plain");
        assert_eq!(format!("{e2:#}"), "plain");
        assert_eq!(format!("{e2:?}"), "plain");
    }

    fn bails() -> crate::Result<()> {
        crate::bail!("bailed with {}", "detail")
    }

    #[test]
    fn bail_returns_error() {
        assert_eq!(bails().unwrap_err().to_string(), "bailed with detail");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync + 'static>() {}
        assert_send_sync::<crate::Error>();
    }
}
