//! Interface stub for the XLA/PJRT bindings.
//!
//! The real `xla` crate links the PJRT CPU plugin; that native dependency
//! is not available in this build environment.  This stub mirrors exactly
//! the API subset `rnn_hls::runtime::engine` uses, so the crate compiles
//! and every PJRT-dependent path fails *at runtime* with a clear message
//! (the serving stack falls back to the pure-rust `fixed`/`float`
//! engines, which is also what `--engine fixed|float` selects).
//!
//! Entry point for reinstating the real backend: implement
//! [`PjRtClient::cpu`] against the actual bindings — every other method
//! is only reachable once `cpu()` succeeds.

#![allow(dead_code)]

use std::fmt;

const UNAVAILABLE: &str = "XLA/PJRT backend is not available in this build \
     (stub `xla` crate): use the `fixed` or `float` engines, or rebuild \
     with the real PJRT bindings";

/// Stub error: always "backend unavailable".
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Self(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (never successfully constructed by the stub).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// The real crate spins up the PJRT CPU plugin here; the stub reports
    /// the backend missing.
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A host-side literal value.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

/// A compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (text interchange).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn hlo_load_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}
