//! Precision sweep on one real trained model: the Fig. 2 experiment as a
//! focused example, printing AUC ratio vs fractional bits and the weight
//! dynamic range that explains the integer-bit requirement.
//!
//! ```text
//! cargo run --release --example precision_sweep [model_key] [samples]
//! ```

use rnn_hls::config::Fig2Config;
use rnn_hls::model::Weights;
use rnn_hls::report::fig2;
use rnn_hls::runtime::manifest;

fn main() -> anyhow::Result<()> {
    let artifacts = manifest::default_artifacts_dir();
    let mut args = std::env::args().skip(1);
    let key = args.next().unwrap_or_else(|| "top_gru".into());
    let samples: usize = args
        .next()
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(800);

    let weights = Weights::load(artifacts.join(format!("weights/{key}.json")))?;
    let (lo, hi) = weights.weight_range();
    println!(
        "model {key}: {} parameters, weight range [{lo:.3}, {hi:.3}]",
        weights.arch.param_count()
    );
    println!(
        "=> integer bits must cover ±{:.1} plus accumulation headroom;\n\
        the paper settles on {} integer bits for this benchmark\n",
        lo.abs().max(hi),
        rnn_hls::hls::paper::chosen_integer_bits(&weights.arch.name),
    );

    let cfg = Fig2Config {
        keys: vec![key.clone()],
        samples,
        ..Default::default()
    };
    let points = fig2::run(&artifacts, &cfg, None)?;
    fig2::shape_check(&points, &key)?;
    println!("shape check OK: ratio saturates at high fractional bits");

    // Find the cheapest (fewest total bits) config within 1% of float.
    let best = points
        .iter()
        .filter(|p| p.ratio() > 0.99)
        .min_by_key(|p| p.integer_bits + p.fractional_bits);
    if let Some(p) = best {
        println!(
            "cheapest near-lossless type: ap_fixed<{},{}> (ratio {:.4})",
            p.integer_bits + p.fractional_bits,
            p.integer_bits,
            p.ratio()
        );
    }
    Ok(())
}
