//! **End-to-end driver** (EXPERIMENTS.md §E2E): the full system on a real
//! small workload, proving all layers compose.
//!
//! A synthetic LHC-style event stream (Poisson arrivals) is served by the
//! trigger coordinator running the AOT-compiled JAX/Pallas model through
//! PJRT — Python never runs.  The demo sweeps the arrival rate, reports
//! drop rate / online accuracy / latency percentiles / throughput at each
//! point, then prints the analytical FPGA estimate for the same network
//! so the CPU-serving numbers can be put in the paper's context.
//!
//! ```text
//! cargo run --release --example trigger_serving [model_key] [events]
//! ```

use std::time::Duration;

use rnn_hls::coordinator::{
    BatcherConfig, Server, ServerConfig, SourceConfig,
};
use rnn_hls::data::generators;
use rnn_hls::fixed::FixedSpec;
use rnn_hls::hls::{latency, paper, HlsConfig, HlsDesign};
use rnn_hls::runtime::{manifest, Runtime};

struct PjrtRunner {
    runtime: Runtime,
    key: String,
    buckets: Vec<usize>,
}

impl rnn_hls::coordinator::BatchRunner for PjrtRunner {
    fn max_batch(&self) -> usize {
        *self.buckets.last().expect("buckets")
    }
    fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let bucket = self
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or(self.max_batch());
        self.runtime.model(&self.key, bucket)?.run_batch(xs, n)
    }
}

fn main() -> anyhow::Result<()> {
    let artifacts = manifest::default_artifacts_dir();
    let mut args = std::env::args().skip(1);
    let key = args.next().unwrap_or_else(|| "top_gru".into());
    let n_events: usize = args
        .next()
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(40_000);
    let benchmark = key.split('_').next().unwrap().to_string();

    println!("=== trigger serving demo: {key}, {n_events} events/point ===\n");

    for rate_hz in [5_000.0, 15_000.0, 30_000.0, 60_000.0] {
        let cfg = ServerConfig {
            workers: 2,
            queue_capacity: 8_192,
            batcher: BatcherConfig {
                max_batch: 100,
                max_wait: Duration::from_micros(200),
            },
            source: SourceConfig {
                rate_hz,
                poisson: true,
                n_events,
            },
        };
        let generator = generators::for_benchmark(&benchmark, 0x5EED)?;
        let artifacts2 = artifacts.clone();
        let key2 = key.clone();
        let report = Server::run(cfg, generator, move || {
            let runtime = Runtime::new(&artifacts2)?;
            let buckets = runtime.manifest().batch_buckets(&key2)?;
            // Precompile every bucket before signalling ready (§Perf).
            for &b in &buckets {
                runtime.model(&key2, b)?;
            }
            Ok(Box::new(PjrtRunner {
                runtime,
                key: key2.clone(),
                buckets,
            }) as Box<dyn rnn_hls::coordinator::BatchRunner>)
        })?;
        println!("--- offered rate {rate_hz:.0} ev/s ---");
        println!("{}\n", report.render());
    }

    // Context: what the FPGA design would sustain (analytical model).
    let runtime = Runtime::new(&artifacts)?;
    let entry = runtime.manifest().model(&key)?;
    let arch = rnn_hls::model::zoo::arch(&benchmark, entry.cell.parse()?)?;
    let reuse = paper::reuse_grid(&benchmark, arch.cell)[0];
    let cfg = HlsConfig::paper_default(FixedSpec::default16_6(), reuse);
    let timing = latency::schedule(&arch, &cfg)?;
    let synth = HlsDesign::new(arch, cfg)?.synthesize()?;
    println!("=== FPGA context (analytical HLS model) ===");
    println!("{}", synth.summary());
    println!(
        "static-mode FPGA throughput at 200 MHz: {:.0} ev/s (II {} cycles)",
        timing.throughput_hz, timing.ii_cycles
    );
    Ok(())
}
