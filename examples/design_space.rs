//! Design-space exploration with the analytical HLS model (no artifacts
//! needed): for each benchmark × cell, scan width × reuse × mode and
//! print which configurations meet a latency budget *and* fit the target
//! device — the workflow a trigger group would actually run before
//! committing firmware.
//!
//! ```text
//! cargo run --release --example design_space [latency_budget_us]
//! ```

use rnn_hls::fixed::FixedSpec;
use rnn_hls::hls::{
    latency::Strategy, paper, Device, HlsConfig, HlsDesign, ReuseFactor,
    RnnMode,
};
use rnn_hls::model::{zoo, Cell};
use rnn_hls::report::AsciiTable;

fn main() -> anyhow::Result<()> {
    let budget_us: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(10.0);
    println!("latency budget: {budget_us} µs (L1T-scale)\n");

    for name in ["top", "flavor", "quickdraw"] {
        let device = Device::for_benchmark(name);
        let mut table = AsciiTable::new(
            format!("{name} design space on {}", device.name),
            &["model", "strategy/mode", "R", "W", "latency µs", "II", "DSP%", "LUT%", "verdict"],
        );
        for cell in [Cell::Gru, Cell::Lstm] {
            let arch = zoo::arch(name, cell)?;
            let mut candidates: Vec<HlsConfig> = Vec::new();
            for reuse in paper::reuse_grid(name, cell) {
                for width in [10u32, 14, 16, 18] {
                    let integer =
                        paper::chosen_integer_bits(name).min(width - 1);
                    candidates.push(HlsConfig::paper_default(
                        FixedSpec::new(width, integer),
                        reuse,
                    ));
                }
            }
            // Latency strategy + non-static variants for the small model.
            if arch.param_count() < 40_000 {
                for mode in [RnnMode::Static, RnnMode::NonStatic] {
                    let mut cfg = HlsConfig::paper_default(
                        FixedSpec::new(16, 6),
                        ReuseFactor::fully_parallel(),
                    );
                    cfg.strategy = Strategy::Latency;
                    cfg.mode = mode;
                    candidates.push(cfg);
                }
            }
            for cfg in candidates {
                let report =
                    HlsDesign::new(arch.clone(), cfg)?.synthesize_for(device)?;
                let (lut_u, _ff, dsp_u, _b) =
                    device.utilization(&report.resources);
                let meets = report.timing.latency_us <= budget_us;
                let verdict = match (meets, report.fits_device) {
                    (true, true) => "OK",
                    (true, false) => "too big",
                    (false, true) => "too slow",
                    (false, false) => "both",
                };
                table.row(vec![
                    report.arch_key.clone(),
                    format!("{}/{}", cfg.strategy.label(), cfg.mode.label()),
                    cfg.reuse.label(),
                    cfg.spec.width.to_string(),
                    format!("{:.2}", report.timing.latency_us),
                    report.timing.ii_cycles.to_string(),
                    format!("{:.0}", dsp_u * 100.0),
                    format!("{:.0}", lut_u * 100.0),
                    verdict.to_string(),
                ]);
            }
        }
        println!("{}", table.render());
    }
    println!(
        "verdict legend: OK = meets {budget_us} µs and fits; the paper's §5 \
         narrative\n(top/flavor fit a VU9P SLR, QuickDraw needs a U250, \
         non-static only at tiny widths)\nfalls out of this scan."
    );
    Ok(())
}
