//! **Library-embedding quickstart** (referenced from README §Serving):
//! the full Session lifecycle — spec → start → submit → snapshot →
//! shutdown — driven from two concurrent submitter threads, with no
//! artifacts needed (synthetic weights, float engine).
//!
//! ```text
//! cargo run --release --example embed_session
//! ```
//!
//! What it shows:
//!
//! * a typed [`ServingSpec`] built with struct-update syntax — all the
//!   validation (shards, batch sizes, arities) happens in one place,
//!   `spec.build()`, inside `Session::start`;
//! * two producer threads sharing one fabric through cloned
//!   [`SessionHandle`]s, with backpressure surfaced as a typed
//!   `SubmitError` instead of a silent drop;
//! * the completion channel (`recv`) matching outputs back to request
//!   ids;
//! * a live `snapshot()` mid-stream, then the final `ShardedReport`
//!   from `shutdown()`.

use std::time::Duration;

// `rnn_hls::api` is the stable import path for the serving surface —
// prefer it over reaching into `coordinator::session` directly (the
// module tree is a layout detail; `api` is the contract).
use rnn_hls::api::{BackendKind, ErrorCode, ServingSpec, Session};
use rnn_hls::coordinator::EngineRunner;
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::FloatEngine;

const PER_THREAD: usize = 2_000;

fn main() -> anyhow::Result<()> {
    // 1. Spec: a 2-shard float session, round-robin routing, modest
    //    batching.  Everything else keeps the defaults.
    let spec = ServingSpec {
        engine: BackendKind::Float,
        shards: 2,
        shard_policy: rnn_hls::coordinator::ShardPolicy::RoundRobin,
        workers: 1,
        queue_capacity: 8_192,
        ..ServingSpec::default()
    }
    .with_batcher(16, Duration::from_micros(200));

    // 2. Start: the factory runs once per worker, inside that worker's
    //    thread, and builds this shard's engine (synthetic weights — no
    //    `make artifacts` required).
    let arch = zoo::arch("top", Cell::Gru)?;
    let weights = Weights::synthetic(&arch, 0x5EED);
    let session = Session::start(&spec, move |_shard| {
        let engine = FloatEngine::new(&weights)?;
        Ok(Box::new(EngineRunner::new(Box::new(engine), 16))
            as Box<dyn rnn_hls::coordinator::BatchRunner>)
    })?;

    // 3. Submit from two threads: each owns a cloned SessionHandle and
    //    pushes its own stream of synthetic events into the one fabric.
    let stride = arch.seq_len * arch.input_size;
    std::thread::scope(|scope| {
        for submitter in 0..2u64 {
            let handle = session.handle();
            scope.spawn(move || {
                let mut rejected = 0u64;
                for i in 0..PER_THREAD as u64 {
                    let mut features = vec![0.0f32; stride];
                    features[0] = (submitter * 1_000 + i % 97) as f32 * 1e-3;
                    // Typed backpressure: a full queue hands the request
                    // back with the same stable numeric code
                    // (`ErrorCode::Shed`) a TCP client would see as a
                    // SHED frame; this demo just counts it as shed load.
                    if let Err(err) =
                        handle.submit_event(features, (i % 2) as u32)
                    {
                        assert_eq!(err.code(), ErrorCode::Shed);
                        rejected += 1;
                    }
                }
                println!(
                    "submitter {submitter}: {PER_THREAD} sent, \
                     {rejected} rejected (backpressure)"
                );
            });
        }
    });

    // 4. Live monitoring while the fabric drains: same exact roll-up as
    //    the final report, taken mid-flight.
    let snap = session.snapshot();
    println!(
        "\nlive snapshot: {} admitted, {} completed so far, mean batch \
         {:.2}",
        snap.merged.generated, snap.merged.completed, snap.merged.mean_batch
    );

    // Completions: every served request comes back with its id and its
    // enqueue/complete instants on the serving clock.
    let mut served = 0usize;
    let expect = (snap.merged.generated - snap.merged.dropped) as usize;
    let mut worst_us = 0.0f64;
    while served < expect {
        let completion = session.recv().expect("fabric alive");
        let latency = completion
            .completed_at
            .saturating_duration_since(completion.enqueued_at);
        worst_us = worst_us.max(latency.as_secs_f64() * 1e6);
        served += 1;
    }
    println!("{served} completions received, worst latency {worst_us:.1} µs");

    // 5. Shutdown: drain-then-close, then the final report.
    let report = session.shutdown()?;
    println!("\n{}", report.render());
    anyhow::ensure!(
        report.merged.completed + report.merged.dropped
            == 2 * PER_THREAD as u64,
        "every submitted event must be accounted for"
    );
    Ok(())
}
