//! Quickstart: load one AOT-compiled model, classify a freshly generated
//! event through every engine, and print the HLS synthesis estimate for
//! the same network — the whole three-layer story in ~80 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//! Requires `make artifacts` to have been run once.

use rnn_hls::coordinator::server::predicted_label;
use rnn_hls::data::generators;
use rnn_hls::fixed::{FixedSpec, QuantConfig};
use rnn_hls::hls::{HlsConfig, HlsDesign};
use rnn_hls::model::Weights;
use rnn_hls::nn::{Engine, FixedEngine, FloatEngine};
use rnn_hls::runtime::{manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let artifacts = manifest::default_artifacts_dir();
    let key = std::env::args().nth(1).unwrap_or_else(|| "top_gru".into());
    let benchmark = key.split('_').next().unwrap().to_string();

    // 1. Generate one live event (the workload the trigger would see).
    let mut generator = generators::for_benchmark(&benchmark, 7)?;
    let event = generator.generate();
    println!("generated one {benchmark} event, true label = {}", event.label);

    // 2. PJRT engine: the AOT-compiled JAX/Pallas model (the request path).
    let runtime = Runtime::new(&artifacts)?;
    let model = runtime.model(&key, 1)?;
    let t0 = std::time::Instant::now();
    let pjrt_out = &model.run_batch(&event.features, 1)?[0];
    println!(
        "pjrt  engine: probs {:?} -> label {} ({:.1} µs)",
        pjrt_out,
        predicted_label(pjrt_out),
        t0.elapsed().as_secs_f64() * 1e6
    );

    // 3. f32 rust engine (reference numerics).
    let weights = Weights::load(artifacts.join(format!("weights/{key}.json")))?;
    let float_engine = FloatEngine::new(&weights)?;
    let float_out = float_engine.forward(&event.features);
    println!(
        "float engine: probs {:?} -> label {}",
        float_out,
        predicted_label(&float_out)
    );

    // 4. Bit-accurate ap_fixed<16,6> engine (the FPGA datapath stand-in).
    let fixed_engine = FixedEngine::new(
        &weights,
        QuantConfig::ptq(FixedSpec::default16_6()),
    )?;
    let fixed_out = fixed_engine.forward(&event.features);
    println!(
        "fixed<16,6> : probs {:?} -> label {}",
        fixed_out,
        predicted_label(&fixed_out)
    );

    // 5. What would this cost on the FPGA?  Ask the HLS model.
    let reuse = rnn_hls::hls::paper::reuse_grid(&benchmark, weights.arch.cell)[0];
    let report = HlsDesign::new(
        weights.arch.clone(),
        HlsConfig::paper_default(FixedSpec::default16_6(), reuse),
    )?
    .synthesize()?;
    println!("\nHLS synthesis estimate:\n{}", report.summary());
    println!(
        "\n(`rnn-hls sweep --benchmark {benchmark}` explores alternatives,\n\
         `rnn-hls report all` regenerates every paper table/figure)"
    );
    Ok(())
}
