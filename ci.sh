#!/usr/bin/env bash
# Tier-1 verify + lint gates.  Invoked by .github/workflows/ci.yml and
# runnable locally:
#   ./ci.sh                # full gates: build, test, invariant lint,
#                          # fmt, clippy, doc
#   ./ci.sh --bench-smoke  # reduced-iteration serving + kernel benches;
#                          # emits BENCH_serving.json and
#                          # BENCH_kernels.json (CI uploads both as
#                          # artifacts to track the perf trajectory)
#   ./ci.sh --analysis     # concurrency analysis: invariant lint +
#                          # model-check interleaving suite
#                          # (cargo test --features model-check)
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" == "--analysis" ]]; then
    # The lint's own negative suite first: a rule that silently stopped
    # matching must fail the build, not pass it.
    echo "== analysis: invariant lint self-test =="
    cargo run --release -p rnn-hls --bin lint -- --self-test
    echo "== analysis: invariant lint (rust/src rust/tests) =="
    cargo run --release -p rnn-hls --bin lint -- rust/src rust/tests
    # The model checker explores the serving fabric's interleavings
    # (tests/model_check.rs) and re-checks the whole suite with the
    # instrumented primitives swapped in.  On failure the harness
    # prints a MODEL_CHECK_TRACE/MODEL_CHECK_SEED replay line.
    echo "== analysis: cargo test -q -p rnn-hls --features model-check =="
    cargo test -q -p rnn-hls --features model-check
    echo "ci.sh --analysis: all gates passed"
    exit 0
fi

if [[ "${1:-}" == "--bench-smoke" ]]; then
    echo "== bench-smoke: throughput_batch --smoke =="
    # Absolute path: cargo runs bench binaries with cwd at the package
    # root (rust/), not the workspace root this script checks from.
    cargo bench --bench throughput_batch -- --smoke --json "$PWD/BENCH_serving.json"
    echo "== bench-smoke: BENCH_serving.json =="
    test -s BENCH_serving.json
    cat BENCH_serving.json
    echo "== bench-smoke: per-backend schema check =="
    # Schema, not perf: the artifact must carry per-backend rows with
    # their batcher columns (schema v5) so per-tier latency stays
    # comparable across PRs *together with the batching policy it was
    # measured under*.  The writer emits compact JSON (no spaces
    # around ':').
    grep -q '"schema_version":5' BENCH_serving.json
    grep -q '"backend":"fixed"' BENCH_serving.json
    grep -q '"backend":"float"' BENCH_serving.json
    grep -q '"config":"mixed90_10_fixed_w2"' BENCH_serving.json
    # Tier-aware batching rows: trigger tier pinned at batch-1/zero-wait,
    # offline tier batching deep, each row carrying its batcher columns.
    # The writer emits max_batch and max_wait_us adjacently, so the pair
    # is grepped as one anchored unit ('"max_batch":1' alone would also
    # match 16/128 and silently pass a broken policy).
    grep -q '"config":"tier_batch_fixed_w2"' BENCH_serving.json
    grep -q '"config":"tier_batch_float_w2"' BENCH_serving.json
    grep -q '"max_batch":1,"max_wait_us":0,' BENCH_serving.json
    grep -q '"max_batch":64,"max_wait_us":2000,' BENCH_serving.json
    # Session-API overhead rows (schema v4): the live request-driven
    # path must be tracked next to the replay path it wraps.
    grep -q '"config":"session_replay_w2"' BENCH_serving.json
    grep -q '"config":"session_submit_w2"' BENCH_serving.json
    # Network saturation curve (schema v5): the loadgen ladder drives
    # real sockets at three offered rates; every point must land as a
    # merged row plus per-tier rows, each carrying the offered rate and
    # the shed count so overload behaviour stays tracked across PRs.
    grep -q '"config":"loadgen_r20k_merged_w2"' BENCH_serving.json
    grep -q '"config":"loadgen_r100k_merged_w2"' BENCH_serving.json
    grep -q '"config":"loadgen_r400k_merged_w2"' BENCH_serving.json
    grep -q '"config":"loadgen_r400k_fixed_w2"' BENCH_serving.json
    grep -q '"config":"loadgen_r400k_float_w2"' BENCH_serving.json
    grep -q '"offered_hz":' BENCH_serving.json
    grep -q '"shed":' BENCH_serving.json
    echo "per-backend rows + batcher columns + session rows + loadgen saturation rows present"

    echo "== bench-smoke: hot_paths --smoke (kernels + allocation) =="
    cargo bench --bench hot_paths -- --smoke --json "$PWD/BENCH_kernels.json"
    echo "== bench-smoke: BENCH_kernels.json =="
    test -s BENCH_kernels.json
    cat BENCH_kernels.json
    echo "== bench-smoke: kernel schema check =="
    # Schema, not perf: scalar rows must always be present, and the
    # dispatched rows must ride next to them so SIMD-vs-scalar stays
    # comparable across PRs.  simd_compiled/simd_active record whether
    # the dispatched rows actually exercised the vector path on this
    # runner (feature-independent: both keys exist either way).
    grep -q '"bench":"kernels"' BENCH_kernels.json
    grep -q '"schema_version":1' BENCH_kernels.json
    grep -q '"simd_compiled":' BENCH_kernels.json
    grep -q '"simd_active":' BENCH_kernels.json
    grep -q '"allocs_per_roundtrip":' BENCH_kernels.json
    grep -q '"name":"float/matmul_acc"' BENCH_kernels.json
    grep -q '"name":"float/matmul_acc_scalar"' BENCH_kernels.json
    grep -q '"name":"fixed/matmul_acc"' BENCH_kernels.json
    grep -q '"name":"fixed/matmul_acc_scalar"' BENCH_kernels.json
    echo "kernel rows (dispatched + scalar, both engines) + alloc row present"

    echo "== bench-smoke: accuracy sweep (bundled trained checkpoint) =="
    # Real-weights accuracy artifact: the bundled top_gru fixture + frozen
    # test slice through the float engine and the fixed-point ladder.
    cargo run --release -p rnn-hls --bin rnn-hls -- accuracy \
        --json "$PWD/BENCH_accuracy.json"
    echo "== bench-smoke: BENCH_accuracy.json =="
    test -s BENCH_accuracy.json
    cat BENCH_accuracy.json
    echo "== bench-smoke: accuracy schema check =="
    # Schema, not values: the AUC goldens themselves are pinned by the
    # tier-1 accuracy_golden suite; here the artifact must carry the
    # float baseline plus per-precision rows (width/integer emitted
    # adjacently, so the pair greps as one anchored unit).
    grep -q '"bench":"accuracy"' BENCH_accuracy.json
    grep -q '"schema_version":1' BENCH_accuracy.json
    grep -q '"key":"top_gru"' BENCH_accuracy.json
    grep -q '"auc_float":' BENCH_accuracy.json
    grep -q '"width":16,"integer":6,' BENCH_accuracy.json
    grep -q '"width":20,"integer":8,' BENCH_accuracy.json
    grep -q '"delta":' BENCH_accuracy.json
    echo "accuracy rows (float baseline + fixed ladder) present"

    echo "== bench-smoke: design-space explore (Pareto front artifact) =="
    # Small measured sweep: top GRU over the default reuse/precision/
    # strategy/clock ladders on the KU115, with the checkpoint's
    # per-precision AUC joined in, pruned to the Pareto front.
    cargo run --release -p rnn-hls --bin rnn-hls -- explore \
        --model top_gru --device ku115 --accuracy \
        --json "$PWD/BENCH_explore.json"
    echo "== bench-smoke: BENCH_explore.json =="
    test -s BENCH_explore.json
    cat BENCH_explore.json
    echo "== bench-smoke: explore schema check =="
    # Schema, not values: front soundness and budget queries are pinned
    # by the tier-1 hls_explore suite; here the artifact must carry the
    # request echo plus per-row design identity, modeled cost, measured
    # AUC, and the serving-bridge columns.
    grep -q '"bench":"explore"' BENCH_explore.json
    grep -q '"schema_version":1' BENCH_explore.json
    grep -q '"device":"KU115"' BENCH_explore.json
    grep -q '"model":"top_gru"' BENCH_explore.json
    grep -q '"reuse_kernel":' BENCH_explore.json
    grep -q '"strategy":' BENCH_explore.json
    grep -q '"clock_mhz":' BENCH_explore.json
    grep -q '"latency_ns":' BENCH_explore.json
    grep -q '"auc":' BENCH_explore.json
    grep -q '"backend":"fixed"' BENCH_explore.json
    grep -q '"tier":' BENCH_explore.json
    echo "explore rows (design identity + cost + AUC + tier) present"
    exit 0
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# The SIMD leg: same build + full suite with the vector kernels compiled
# in.  tests/kernel_equivalence.rs pins dispatched == scalar bitwise, so
# this leg is what actually proves the AVX2 path safe to ship; without
# the feature those tests still run but compare scalar to itself.
echo "== tier-1: cargo build --release --features simd =="
cargo build --release -p rnn-hls --features simd
echo "== tier-1: cargo test -q --features simd =="
cargo test -q -p rnn-hls --features simd

# Redundant with the full suite above, but pinned as its own gate so the
# deterministic virtual-clock deadline suite can never be silently
# filtered out of the matrix toolchains.
echo "== tier-1: cargo test -q --test tier_batching (virtual-clock suite) =="
cargo test -q --test tier_batching

# Same reasoning for the network front-end: the wire-framing property
# tests and the TCP-vs-in-process bitwise-identity suite are the only
# guard on the socket path, so they get their own pinned gate.
echo "== tier-1: cargo test -q --test net_ingest (wire + socket suite) =="
cargo test -q --test net_ingest

# And for the accuracy contract: the golden suite pins the float AUC of
# the bundled trained checkpoint against the python reference and the
# fixed-vs-float deltas across the precision ladder — the only guard
# that the weight importers produce a *working* network, not just
# well-shaped tensors.
echo "== tier-1: cargo test -q --test accuracy_golden (import + AUC goldens) =="
cargo test -q --test accuracy_golden
echo "== tier-1: cargo test -q --test weight_import (ONNX/JSON importers) =="
cargo test -q --test weight_import

# And for the design-space explorer: Pareto-front soundness (no survivor
# dominated, every pruned row names a surviving dominator), budget
# queries as true minima over the unpruned grid, and byte-stable
# BENCH_explore.json output.
echo "== tier-1: cargo test -q --test hls_explore (design-space explorer) =="
cargo test -q --test hls_explore

# Invariant lint (tools/lint): sync primitives confined to the
# util::sync gateway, SeqCst on accounting writes, lock_or_recover
# instead of unwrap on lock results, allowlisted + SAFETY-commented
# unsafe.  Self-test first — a rule that stopped matching must fail
# here, not silently pass the scan.
echo "== invariant lint: self-test =="
cargo run --release -p rnn-hls --bin lint -- --self-test
echo "== invariant lint: rust/src rust/tests =="
cargo run --release -p rnn-hls --bin lint -- rust/src rust/tests

# Lint gates.  Locally they degrade to a skip when the rustup component
# is absent; under CI ($CI is set on GitHub Actions, which installs both
# components) a missing component is a hard failure — the lint gates are
# part of tier 1, not best-effort.
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
elif [[ -n "${CI:-}" ]]; then
    echo "cargo fmt is required in CI but not installed" >&2
    exit 1
else
    echo "== cargo fmt --check == (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets -D warnings =="
    cargo clippy --all-targets -- -D warnings
elif [[ -n "${CI:-}" ]]; then
    echo "cargo clippy is required in CI but not installed" >&2
    exit 1
else
    echo "== cargo clippy == (skipped: clippy not installed)"
fi

echo "== cargo doc --no-deps (rustdoc warnings gate) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p rnn-hls

echo "ci.sh: all gates passed"
