#!/usr/bin/env bash
# Tier-1 verify + lint gates.  Invoked by .github/workflows/ci.yml and
# runnable locally: ./ci.sh
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# Lint gates: run when the components are installed (rustfmt/clippy are
# rustup components and may be absent in minimal toolchains).
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt --check == (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== cargo clippy == (skipped: clippy not installed)"
fi

echo "ci.sh: all gates passed"
