#!/usr/bin/env bash
# Tier-1 verify + lint gates.  Invoked by .github/workflows/ci.yml and
# runnable locally:
#   ./ci.sh                # full gates: build, test, fmt, clippy, doc
#   ./ci.sh --bench-smoke  # reduced-iteration serving bench; emits
#                          # BENCH_serving.json (CI uploads it as an
#                          # artifact to track the perf trajectory)
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    echo "== bench-smoke: throughput_batch --smoke =="
    # Absolute path: cargo runs bench binaries with cwd at the package
    # root (rust/), not the workspace root this script checks from.
    cargo bench --bench throughput_batch -- --smoke --json "$PWD/BENCH_serving.json"
    echo "== bench-smoke: BENCH_serving.json =="
    test -s BENCH_serving.json
    cat BENCH_serving.json
    echo "== bench-smoke: per-backend schema check =="
    # Schema, not perf: the artifact must carry per-backend rows with
    # their batcher columns (schema v4) so per-tier latency stays
    # comparable across PRs *together with the batching policy it was
    # measured under*.  The writer emits compact JSON (no spaces
    # around ':').
    grep -q '"schema_version":4' BENCH_serving.json
    grep -q '"backend":"fixed"' BENCH_serving.json
    grep -q '"backend":"float"' BENCH_serving.json
    grep -q '"config":"mixed90_10_fixed_w2"' BENCH_serving.json
    # Tier-aware batching rows: trigger tier pinned at batch-1/zero-wait,
    # offline tier batching deep, each row carrying its batcher columns.
    # The writer emits max_batch and max_wait_us adjacently, so the pair
    # is grepped as one anchored unit ('"max_batch":1' alone would also
    # match 16/128 and silently pass a broken policy).
    grep -q '"config":"tier_batch_fixed_w2"' BENCH_serving.json
    grep -q '"config":"tier_batch_float_w2"' BENCH_serving.json
    grep -q '"max_batch":1,"max_wait_us":0,' BENCH_serving.json
    grep -q '"max_batch":64,"max_wait_us":2000,' BENCH_serving.json
    # Session-API overhead rows (schema v4): the live request-driven
    # path must be tracked next to the replay path it wraps.
    grep -q '"config":"session_replay_w2"' BENCH_serving.json
    grep -q '"config":"session_submit_w2"' BENCH_serving.json
    echo "per-backend rows + batcher columns + session rows present"
    exit 0
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# Redundant with the full suite above, but pinned as its own gate so the
# deterministic virtual-clock deadline suite can never be silently
# filtered out of the matrix toolchains.
echo "== tier-1: cargo test -q --test tier_batching (virtual-clock suite) =="
cargo test -q --test tier_batching

# Lint gates.  Locally they degrade to a skip when the rustup component
# is absent; under CI ($CI is set on GitHub Actions, which installs both
# components) a missing component is a hard failure — the lint gates are
# part of tier 1, not best-effort.
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
elif [[ -n "${CI:-}" ]]; then
    echo "cargo fmt is required in CI but not installed" >&2
    exit 1
else
    echo "== cargo fmt --check == (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets -D warnings =="
    cargo clippy --all-targets -- -D warnings
elif [[ -n "${CI:-}" ]]; then
    echo "cargo clippy is required in CI but not installed" >&2
    exit 1
else
    echo "== cargo clippy == (skipped: clippy not installed)"
fi

echo "== cargo doc --no-deps (rustdoc warnings gate) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p rnn-hls

echo "ci.sh: all gates passed"
