#!/usr/bin/env bash
# Tier-1 verify + lint gates.  Invoked by .github/workflows/ci.yml and
# runnable locally:
#   ./ci.sh                # full gates: build, test, fmt, clippy, doc
#   ./ci.sh --bench-smoke  # reduced-iteration serving bench; emits
#                          # BENCH_serving.json (CI uploads it as an
#                          # artifact to track the perf trajectory)
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    echo "== bench-smoke: throughput_batch --smoke =="
    # Absolute path: cargo runs bench binaries with cwd at the package
    # root (rust/), not the workspace root this script checks from.
    cargo bench --bench throughput_batch -- --smoke --json "$PWD/BENCH_serving.json"
    echo "== bench-smoke: BENCH_serving.json =="
    test -s BENCH_serving.json
    cat BENCH_serving.json
    echo "== bench-smoke: per-backend schema check =="
    # Schema, not perf: the artifact must carry per-backend rows (schema
    # v2) so per-tier latency stays comparable across PRs.  The writer
    # emits compact JSON (no spaces around ':').
    grep -q '"schema_version":2' BENCH_serving.json
    grep -q '"backend":"fixed"' BENCH_serving.json
    grep -q '"backend":"float"' BENCH_serving.json
    grep -q '"config":"mixed90_10_fixed_w2"' BENCH_serving.json
    echo "per-backend rows present"
    exit 0
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# Lint gates: run when the components are installed (rustfmt/clippy are
# rustup components and may be absent in minimal toolchains).
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt --check == (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== cargo clippy == (skipped: clippy not installed)"
fi

echo "== cargo doc --no-deps (rustdoc warnings gate) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p rnn-hls

echo "ci.sh: all gates passed"
